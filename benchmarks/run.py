"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Distributed benchmarks run in
subprocesses with 8 placeholder host devices (the main process keeps the
single real device, mirroring the dry-run discipline).
"""
from __future__ import annotations

import sys

from benchmarks.common import run_with_devices

MULTIDEV = [
    ("bench_microbench", "paper Fig 1: localised vs non-localised microbench"),
    ("bench_sort_cases", "paper Table 1 + Fig 2: merge sort cases 1-8"),
    ("bench_sort_sizes", "paper Fig 3: input-size sweep"),
    ("bench_striping", "paper Fig 4: striping analogue"),
]
LOCAL = [
    ("bench_kernels", "Pallas kernel localisation (Fig 1, TPU-native)"),
    ("bench_roofline", "dry-run roofline table (EXPERIMENTS.md)"),
]


def main() -> None:
    for mod, desc in MULTIDEV:
        print(f"# === {mod}: {desc} ===", flush=True)
        out = run_with_devices(mod, n_devices=8)
        sys.stdout.write(out)
        sys.stdout.flush()
    for mod, desc in LOCAL:
        print(f"# === {mod}: {desc} ===", flush=True)
        m = __import__(f"benchmarks.{mod}", fromlist=["main"])
        m.main()


if __name__ == "__main__":
    main()
