"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV and writes machine-readable
``BENCH_sort.json`` / ``BENCH_microbench.json`` / ``BENCH_engine.json`` /
``BENCH_kernels.json`` (one record per case: name, n, median wall-clock in
us, backend, derived) so the perf trajectory is tracked across PRs
(``benchmarks/compare.py`` diffs two runs).  Distributed benchmarks run in
subprocesses with 8 placeholder host devices (the main process keeps the
single real device, mirroring the dry-run discipline); the LOCAL benches
run in-process with their stdout captured so their CSV reaches
`parse_records` too.

``--smoke`` runs every entry point at toy sizes on 2 placeholder devices —
fast enough for the test suite, so the benchmark surface can't silently rot.

``--check`` runs the homecheck static analyzer (rules R1-R11, see
`repro.analysis`) over each bench family *before* timing it and stamps the
verdict (``"homecheck": "clean"`` / ``"findings:N"`` / ``"failed"``) into
every record the family contributes to BENCH_*.json; the serving families
additionally get the R9 scheduler certificate as ``"schedcheck":
"certified"`` / ``"findings:N"``.  ``compare.py`` then fails a PR whose
previously clean (or certified) case gained findings.
``benchmarks/ci_gate.sh`` additionally stamps a ``"ci_gate"`` verdict
(fast tests + the full analyzer sweep) gated the same way.

``bench_roofline`` reads the committed dry-run artifacts under
``results/dryrun`` — its rows are analytic (compile-only), so its
``BENCH_roofline.json`` baseline is deterministic across machines.
"""
from __future__ import annotations

import argparse
import contextlib
import io
import json
import os
import re
import subprocess
import sys

from benchmarks.common import run_with_devices

# (key, module, description): `key` names the run (a module may appear more
# than once with different argv — the pods grid reuses bench_sort_cases)
MULTIDEV = [
    ("bench_microbench", "bench_microbench",
     "paper Fig 1: localised vs non-localised microbench"),
    ("bench_sort_cases", "bench_sort_cases",
     "paper Table 1 + Fig 2: merge sort cases 1-8"),
    ("bench_sort_pods", "bench_sort_cases",
     "hierarchical multi-pod engine: inter/intra-pod exchange bytes (Fig 9)"),
    ("bench_sort_sizes", "bench_sort_sizes", "paper Fig 3: input-size sweep"),
    ("bench_striping", "bench_striping", "paper Fig 4: striping analogue"),
    ("bench_serve", "bench_serve",
     "home-aware serving scheduler: fifo vs homed, flat mesh"),
    ("bench_serve_pods", "bench_serve",
     "home-aware serving scheduler on the (2,2,2) emulated-pod mesh"),
]
LOCAL = [
    ("bench_kernels", "Pallas kernel localisation (Fig 1, TPU-native)"),
    ("bench_roofline", "dry-run roofline table (EXPERIMENTS.md)"),
]

# per-run argv for the full harness (8 devices)
FULL_ARGS = {
    "bench_sort_pods": ["--pods", "2x4", "--logn", "18"],
    "bench_serve_pods": ["--pods", "2x2x2"],
}

# per-run argv for --smoke: toy sizes, a case subset, short sweeps;
# the pods grid runs on the 2 smoke devices as a (2, 1, 1) emulated mesh
SMOKE_ARGS = {
    "bench_microbench": ["--n", "4096", "--reps", "2"],
    "bench_sort_cases": ["--logn", "12", "--cases", "3,8"],
    "bench_sort_pods": ["--pods", "2x1", "--logn", "10"],
    "bench_sort_sizes": ["--logns", "12"],
    "bench_striping": ["--logn", "14", "--logb", "6"],
    "bench_serve": ["--slots", "4", "--requests", "10", "--max-len", "32",
                    "--short-new", "2", "--long-new", "6", "--sessions", "6",
                    "--reps", "1"],
    "bench_serve_pods": ["--pods", "2x1", "--slots", "4", "--requests", "16",
                         "--max-len", "32", "--short-new", "2",
                         "--long-new", "6", "--sessions", "6", "--reps", "1"],
    "bench_kernels": ["--only", "local,merge", "--chunks", "2",
                      "--logcs", "8"],
}

# --check: homecheck CLI argv per bench family ("{D}" = device count).
# Each entry lowers the family's workload/policy surface and runs rules
# R1-R11 (repro.analysis) on the partitioned HLO + jaxpr + exchange network
# — nothing times until the home contract holds.  Families with no
# collective surface of their own (striping/roofline are local-copy /
# compile-only sweeps) map to an empty list.
CHECK_ARGS = {
    "bench_microbench": [["--workload", "microbench", "--pods", "1x{D}",
                          "--policy", "all"]],
    "bench_sort_cases": [["--workload", "sort", "--pods", "1x{D}",
                          "--policy", "all"],
                         ["--workload", "sort", "--pods", "1x{D}",
                          "--backend", "constraint"]],
    "bench_sort_pods": [["--workload", "sort", "--pods", "{PODS}",
                         "--policy", "all"]],
    "bench_sort_sizes": [["--workload", "sort", "--pods", "1x{D}"]],
    "bench_striping": [],
    "bench_serve": [["--workload", "serve", "--pods", "{SERVE}"]],
    "bench_serve_pods": [["--workload", "serve", "--pods", "{PODS}"]],
    "bench_kernels": [["--workload", "sort"]],   # single device: R3/R4
    "bench_roofline": [],
}
# substitutions for the full (8-device) harness vs --smoke (2 devices)
CHECK_SUBST = {
    False: {"{D}": "8", "{PODS}": "2x2x2", "{SERVE}": "1x4x2"},
    True: {"{D}": "2", "{PODS}": "2x1", "{SERVE}": "1x2"},
}

_CHECK_SUMMARY_RE = re.compile(
    r"homecheck: (\d+) target\(s\), (\d+) finding\(s\), (\d+) error\(s\)")
_R9_OK_RE = re.compile(r"^R9 certificate \[scheduler\]:", re.M)
_R9_BAD_RE = re.compile(r"^R9 certificate FAILED", re.M)


def run_homecheck(key: str, smoke: bool, timeout: int = 600):
    """Run the family's homecheck sweep.

    Returns ``(status, sched)``: status is "clean" | "findings:N" |
    "failed"; sched is the R9 scheduler-certificate verdict ("certified"
    | "findings:N") when the sweep printed one, else None (non-serve
    families).  The CLI subprocess sets its own XLA_FLAGS from --pods, so
    the harness process keeps its single real device (same discipline as
    the benches).
    """
    subst = CHECK_SUBST[smoke]
    findings = 0
    sched = None
    for argv in CHECK_ARGS.get(key, []):
        for k, v in subst.items():
            argv = [a.replace(k, v) for a in argv]
        env = dict(os.environ)
        env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.homecheck", *argv],
            capture_output=True, text=True, timeout=timeout, env=env)
        m = _CHECK_SUMMARY_RE.search(r.stdout)
        if r.returncode not in (0, 1) or m is None:
            print(f"# homecheck {key} DRIVER FAILURE:\n{r.stderr[-2000:]}",
                  file=sys.stderr)
            return "failed", sched
        findings += int(m.group(2))
        if int(m.group(2)):
            sys.stdout.write(r.stdout)
        n_bad = len(_R9_BAD_RE.findall(r.stdout))
        if n_bad:
            sched = f"findings:{n_bad}"
        elif _R9_OK_RE.search(r.stdout) and sched is None:
            sched = "certified"
    status = "clean" if findings == 0 else f"findings:{findings}"
    return status, sched


# json targets: which CSV prefixes land in which BENCH_*.json
JSON_FILES = {
    "BENCH_sort.json": ("sort_",),
    "BENCH_microbench.json": ("microbench_",),
    "BENCH_engine.json": ("engine_",),
    "BENCH_kernels.json": ("kernel_",),
    "BENCH_serve.json": ("serve_",),
    "BENCH_roofline.json": ("roofline_",),
}


def parse_records(csv_text: str):
    """CSV ``name,us_per_call,derived`` rows -> dict records.

    `backend` and `n` are recovered from the benchmark's name convention
    (``sort_<backend>_case<k>_...``, ``sort_<backend>_n<n>_...``); rows
    without a wall-clock (structure-only lines) keep ``us=None``.
    """
    records = []
    for line in csv_text.splitlines():
        line = line.strip()
        if not line or line.startswith("#") or line.startswith("name,"):
            continue
        parts = line.split(",", 2)
        if len(parts) < 2:
            continue
        name, us = parts[0], parts[1]
        derived = parts[2] if len(parts) > 2 else ""
        m_backend = re.match(r"sort_(constraint|shard_map)_", name)
        m_n = re.search(r"_n(\d+)_", name)
        records.append({
            "name": name,
            "n": int(m_n.group(1)) if m_n else None,
            "us": float(us) if us else None,
            "backend": m_backend.group(1) if m_backend else None,
            "derived": derived,
        })
    return records


def write_json(records, out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    for fname, prefixes in JSON_FILES.items():
        rows = [r for r in records if r["name"].startswith(prefixes)]
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"# wrote {path} ({len(rows)} records)", flush=True)


class _Tee(io.TextIOBase):
    """Write-through to several sinks: capture without losing streaming."""

    def __init__(self, *sinks):
        self.sinks = sinks

    def write(self, s):
        for sink in self.sinks:
            sink.write(s)
        return len(s)

    def flush(self):
        for sink in self.sinks:
            sink.flush()


def run_local(mod: str, args=None) -> str:
    """Run a single-process benchmark module, returning its captured CSV.

    The LOCAL benches print from ``main()`` in-process; without capture
    their rows never reached `parse_records`/`write_json` — BENCH_kernels
    stayed empty no matter what ran.  Output still streams to the real
    stdout as it is produced (interpret-mode sweeps take minutes; a silent
    harness reads as hung).
    """
    m = __import__(f"benchmarks.{mod}", fromlist=["main"])
    buf = io.StringIO()
    with contextlib.redirect_stdout(_Tee(sys.stdout, buf)):
        m.main(args or [])
    return buf.getvalue()


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="toy sizes / 2 devices: exercise every entry point")
    ap.add_argument("--out", default=".",
                    help="directory for BENCH_*.json")
    ap.add_argument("--skip-local", action="store_true",
                    help="skip the single-process (non-mesh) benches")
    ap.add_argument("--check", action="store_true",
                    help="run homecheck (R1-R11) over each bench family "
                         "before timing it; the verdict is stamped into "
                         "every BENCH_*.json record (serve families also "
                         "get the R9 scheduler certificate)")
    args = ap.parse_args(argv)
    n_devices = 2 if args.smoke else 8
    records = []

    def precheck(key):
        """Homecheck the family before timing it; None when not checking."""
        if not args.check:
            return None
        status, sched = run_homecheck(key, smoke=args.smoke)
        tail = f", schedcheck: {sched}" if sched else ""
        print(f"# homecheck[{key}]: {status}{tail}", flush=True)
        return status, sched

    def stamp(rows, verdicts):
        if verdicts is not None:
            status, sched = verdicts
            for r in rows:
                r["homecheck"] = status
                if sched is not None:
                    r["schedcheck"] = sched
        return rows

    for key, mod, desc in MULTIDEV:
        print(f"# === {key}: {desc} ===", flush=True)
        extra = (SMOKE_ARGS.get(key, []) if args.smoke
                 else FULL_ARGS.get(key, []))
        status = precheck(key)
        out = run_with_devices(mod, n_devices=n_devices, args=extra)
        sys.stdout.write(out)
        sys.stdout.flush()
        records += stamp(parse_records(out), status)
    if not args.skip_local:
        for mod, desc in LOCAL:
            print(f"# === {mod}: {desc} ===", flush=True)
            status = precheck(mod)
            out = run_local(mod, SMOKE_ARGS.get(mod, []) if args.smoke
                            else FULL_ARGS.get(mod, []))
            records += stamp(parse_records(out), status)
    write_json(records, args.out)


if __name__ == "__main__":
    main()
