"""Paper Table 1 + Fig. 2 — merge sort speed-up ladder, Cases 1-8.

`derived` = speed-up vs the Case-1-style single-worker baseline (paper's
normalisation: 1 thread, default policy).

``--backend constraint`` (default) measures the `with_sharding_constraint`
hint tree; ``--backend shard_map`` measures the explicit execution engine
(`repro.core.engine`); ``--backend both`` prints the grid for each.
``--local-sort`` picks the engine's per-device leaf sort: ``jnp`` (default
here — the Pallas kernel only *interprets* on CPU, drowning the collective
signal) or ``bitonic`` (the VMEM-resident kernel, the TPU configuration).
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.paper_sort import CASES
from repro.core import Homing, LocalisationPolicy
from repro.core.sort import BACKENDS, make_sort_fn
from repro.launch.hlo_cost import analyze
from benchmarks.common import timeit

N = 1 << 21   # 2M int32 (scaled from the paper's 100M for the CPU harness)


def fresh():
    return jax.random.randint(jax.random.key(0), (N,), 0, 1 << 30,
                              dtype=jnp.int32)


def _structure(fn):
    """Per-device HLO facts: the hardware-independent Table-1 signal."""
    compiled = fn.lower(fresh()).compile()
    p = analyze(compiled.as_text())
    return p["bytes"], p["collective_total"]


def run_grid(mesh, n_dev: int, backend: str, local_sort, t_base: float):
    for num, c in sorted(CASES.items()):
        pol = LocalisationPolicy(localised=c.localised,
                                 static_mapping=c.static_mapping,
                                 homing=Homing(c.homing))
        fn = make_sort_fn(mesh, pol, num_workers=n_dev if n_dev > 1 else 8,
                          local_sort=local_sort, backend=backend)
        t = timeit(lambda: fn(fresh()))
        by, coll = _structure(fn)
        print(f"sort_{backend}_case{num}_{pol.name},{t:.0f},"
              f"speedup={t_base / max(t, 1e-9):.2f};"
              f"bytes/dev={by/1e6:.0f}MB;coll/dev={coll/1e6:.1f}MB")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=BACKENDS + ("both",),
                    default="constraint")
    ap.add_argument("--local-sort", choices=("jnp", "bitonic"), default="jnp",
                    help="engine leaf sort (bitonic = Pallas kernel)")
    args = ap.parse_args(argv)
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",)) if n_dev > 1 else None
    local_sort = jnp.sort if args.local_sort == "jnp" else "bitonic"
    print("name,us_per_call,derived")
    # the paper's normalisation: 1 worker, default policy — one shared
    # baseline (the engine is per-device, so it has no 1-worker mode)
    base_fn = make_sort_fn(mesh, LocalisationPolicy(False, False,
                                                    Homing.HASH_INTERLEAVED),
                           num_workers=1)
    t_base = timeit(lambda: base_fn(fresh()))
    print(f"sort_case0_1worker_baseline,{t_base:.0f},speedup=1.00")
    backends = BACKENDS if args.backend == "both" else (args.backend,)
    for backend in backends:
        run_grid(mesh, n_dev, backend,
                 local_sort if backend == "shard_map" else None, t_base)


if __name__ == "__main__":
    main()
