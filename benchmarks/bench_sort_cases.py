"""Paper Table 1 + Fig. 2 — merge sort speed-up ladder, Cases 1-8.

`derived` = speed-up vs the Case-1-style single-worker baseline (paper's
normalisation: 1 thread, default policy).

``--backend constraint`` (default) measures the `with_sharding_constraint`
hint tree; ``--backend shard_map`` measures the explicit execution engine
(`repro.core.engine`); ``--backend both`` prints the grid for each.
``--local-sort`` picks the engine's per-device leaf sort: ``jnp`` (default
here — the Pallas kernel only *interprets* on CPU, drowning the collective
signal) or ``bitonic`` (the VMEM-resident kernel, the TPU configuration).
``--logn`` scales the input (smoke runs use a small one).

All placement goes through `Locale`: one locale per Table-1 case, the sort
built with ``locale.workload("sort", backend=...)``.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.paper_sort import CASES
from repro.core import BACKENDS, Homing, Locale, LocalisationPolicy
from repro.launch.hlo_cost import analyze
from benchmarks.common import timeit


def fresh(n):
    return jax.random.randint(jax.random.key(0), (n,), 0, 1 << 30,
                              dtype=jnp.int32)


def _structure(fn, n):
    """Per-device HLO facts: the hardware-independent Table-1 signal."""
    compiled = fn.lower(fresh(n)).compile()
    p = analyze(compiled.as_text())
    return p["bytes"], p["collective_total"]


def run_grid(locale, n_dev: int, backend: str, local_sort, t_base: float,
             n: int, cases=None):
    for num, c in sorted(CASES.items()):
        if cases and num not in cases:
            continue
        pol = LocalisationPolicy(localised=c.localised,
                                 static_mapping=c.static_mapping,
                                 homing=Homing(c.homing))
        fn = locale.with_policy(pol).workload(
            "sort", backend=backend, local_sort=local_sort,
            num_workers=n_dev if n_dev > 1 else 8)
        t = timeit(lambda: fn(fresh(n)))
        by, coll = _structure(fn, n)
        print(f"sort_{backend}_n{n}_case{num}_{pol.name},{t:.0f},"
              f"speedup={t_base / max(t, 1e-9):.2f};"
              f"bytes/dev={by/1e6:.0f}MB;coll/dev={coll/1e6:.1f}MB")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=BACKENDS + ("both",),
                    default="constraint")
    ap.add_argument("--local-sort", choices=("jnp", "bitonic"), default="jnp",
                    help="engine leaf sort (bitonic = Pallas kernel)")
    ap.add_argument("--logn", type=int, default=21,
                    help="log2 input size (2M int32 default, scaled from the "
                         "paper's 100M for the CPU harness)")
    ap.add_argument("--cases", type=lambda s: {int(c) for c in s.split(",")},
                    default=None, help="comma list of Table-1 cases to run")
    args = ap.parse_args(argv)
    n = 1 << args.logn
    n_dev = len(jax.devices())
    locale = Locale.auto()
    local_sort = jnp.sort if args.local_sort == "jnp" else "bitonic"
    print("name,us_per_call,derived")
    # the paper's normalisation: 1 worker, default policy — one shared
    # baseline (the engine is per-device, so it has no 1-worker mode)
    base_fn = locale.with_policy(
        LocalisationPolicy(False, False, Homing.HASH_INTERLEAVED)).workload(
            "sort", num_workers=1)
    t_base = timeit(lambda: base_fn(fresh(n)))
    print(f"sort_n{n}_case0_1worker_baseline,{t_base:.0f},speedup=1.00")
    backends = BACKENDS if args.backend == "both" else (args.backend,)
    for backend in backends:
        run_grid(locale, n_dev, backend,
                 local_sort if backend == "shard_map" else None, t_base,
                 n, args.cases)


if __name__ == "__main__":
    main()
