"""Paper Table 1 + Fig. 2 — merge sort speed-up ladder, Cases 1-8.

`derived` = speed-up vs the Case-1-style single-worker baseline (paper's
normalisation: 1 thread, default policy).
"""
import jax
import jax.numpy as jnp

from repro.configs.paper_sort import CASES
from repro.core import Homing, LocalisationPolicy
from repro.core.sort import make_sort_fn
from repro.launch.hlo_cost import analyze
from benchmarks.common import timeit

N = 1 << 21   # 2M int32 (scaled from the paper's 100M for the CPU harness)


def fresh():
    return jax.random.randint(jax.random.key(0), (N,), 0, 1 << 30,
                              dtype=jnp.int32)


def _structure(fn):
    """Per-device HLO facts: the hardware-independent Table-1 signal."""
    compiled = fn.lower(fresh()).compile()
    p = analyze(compiled.as_text())
    return p["bytes"], p["collective_total"]


def main():
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",)) if n_dev > 1 else None
    print("name,us_per_call,derived")
    base_fn = make_sort_fn(mesh, LocalisationPolicy(False, False,
                                                    Homing.HASH_INTERLEAVED),
                           num_workers=1)
    t_base = timeit(lambda: base_fn(fresh()))
    print(f"sort_case0_1worker_baseline,{t_base:.0f},speedup=1.00")
    for num, c in sorted(CASES.items()):
        pol = LocalisationPolicy(localised=c.localised,
                                 static_mapping=c.static_mapping,
                                 homing=Homing(c.homing))
        fn = make_sort_fn(mesh, pol, num_workers=n_dev if n_dev > 1 else 8)
        t = timeit(lambda: fn(fresh()))
        by, coll = _structure(fn)
        print(f"sort_case{num}_{pol.name},{t:.0f},"
              f"speedup={t_base / max(t, 1e-9):.2f};"
              f"bytes/dev={by/1e6:.0f}MB;coll/dev={coll/1e6:.1f}MB")


if __name__ == "__main__":
    main()
