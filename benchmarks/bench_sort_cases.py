"""Paper Table 1 + Fig. 2 — merge sort speed-up ladder, Cases 1-8.

`derived` = speed-up vs the Case-1-style single-worker baseline (paper's
normalisation: 1 thread, default policy).

``--backend constraint`` (default) measures the `with_sharding_constraint`
hint tree; ``--backend shard_map`` measures the explicit execution engine
(`repro.core.engine`); ``--backend both`` prints the grid for each.
``--local-sort`` picks the engine's per-device leaf sort: ``jnp`` (default
here — the Pallas kernel only *interprets* on CPU, drowning the collective
signal) or ``bitonic`` (the VMEM-resident kernel, the TPU configuration).
``--local-phase`` picks the engine's local-phase implementation: ``auto``
(default: follows --local-sort), ``pallas`` (fused VMEM-resident
local_sort + kept-half merge_split kernels) or ``reference`` (the jnp
oracle).  ``--logn`` scales the input (smoke runs use a small one).

``--pods PxD[xM]`` switches to the hierarchical grid instead: an
emulated-pod (pod, data, model) mesh, the engine run for the hierarchical
policy vs the flat localised / flat non-localised ones, and — the paper's
Fig-9 locality argument made measurable — one ``engine_*_level*`` record
per collective with its inter-pod vs intra-pod exchange bytes
(`repro.core.engine.exchange_schedule`), plus an ``inter_total`` summary
row per policy.  These rows land in ``BENCH_engine.json``.

All placement goes through `Locale`: one locale per Table-1 case, the sort
built with ``locale.workload("sort", backend=...)``.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.paper_sort import CASES
from repro.core import (BACKENDS, Homing, Locale, LocalisationPolicy,
                        exchange_schedule)
from repro.launch.hlo_cost import analyze
from repro.launch.mesh import make_host_mesh
from benchmarks.common import timeit


def fresh(n):
    return jax.random.randint(jax.random.key(0), (n,), 0, 1 << 30,
                              dtype=jnp.int32)


def _structure(fn, n):
    """Per-device HLO facts: the hardware-independent Table-1 signal."""
    compiled = fn.lower(fresh(n)).compile()
    p = analyze(compiled.as_text())
    return p["bytes"], p["collective_total"]


def run_grid(locale, n_dev: int, backend: str, local_sort, t_base: float,
             n: int, cases=None, local_phase=None):
    for num, c in sorted(CASES.items()):
        if cases and num not in cases:
            continue
        pol = LocalisationPolicy(localised=c.localised,
                                 static_mapping=c.static_mapping,
                                 homing=Homing(c.homing))
        fn = locale.with_policy(pol).workload(
            "sort", backend=backend, local_sort=local_sort,
            num_workers=n_dev if n_dev > 1 else 8,
            local_phase=local_phase if backend == "shard_map" else None)
        t = timeit(lambda: fn(fresh(n)))
        by, coll = _structure(fn, n)
        print(f"sort_{backend}_n{n}_case{num}_{pol.name},{t:.0f},"
              f"speedup={t_base / max(t, 1e-9):.2f};"
              f"bytes/dev={by/1e6:.0f}MB;coll/dev={coll/1e6:.1f}MB")


def pod_policies():
    """The hierarchical grid: two-distance-class engine vs the flat paths."""
    return [LocalisationPolicy.hierarchical(),            # intra ppermute +
                                                          # top all_gather
            LocalisationPolicy(True, True, Homing.LOCAL_CHUNKED),   # flat loc
            LocalisationPolicy(False, True, Homing.LOCAL_CHUNKED)]  # flat
                                                          # nonloc: every
                                                          # level crosses DCN


def run_pods(pods: str, logn: int, local_sort, local_phase=None):
    """Hierarchical engine grid on an emulated-pod mesh (--pods PxD[xM])."""
    try:
        dims = [int(d) for d in pods.split("x")]
    except ValueError:
        dims = []
    if len(dims) == 2:
        dims.append(1)
    if len(dims) != 3:
        raise SystemExit(f"--pods wants PxD or PxDxM (e.g. 2x4 or 2x2x2), "
                         f"got {pods!r}")
    n_pods, n_data, n_model = dims
    mesh = make_host_mesh(n_data=n_data, n_model=n_model, n_pods=n_pods)
    locale = Locale(mesh=mesh, axis=("pod", "data"))
    n = 1 << logn
    tag = f"pods{n_pods}x{n_data}x{n_model}"
    sizes = (n_pods, n_data)
    for pol in pod_policies():
        fn = locale.with_policy(pol).workload("sort", backend="shard_map",
                                              local_sort=local_sort,
                                              local_phase=local_phase)
        t = timeit(lambda: fn(fresh(n)))
        # per-record pricing must reflect the phase the timed engine ran
        # (auto resolves by local_sort, exactly as the engine does)
        from repro.core.engine import resolve_local_phase
        phase = resolve_local_phase(local_phase, local_sort)
        sched = exchange_schedule(n, sizes, pol, local_phase=phase)
        inter = sum(r["inter_pod_bytes"] for r in sched)
        intra = sum(r["intra_pod_bytes"] for r in sched)
        # price the local phase under BOTH implementations: the schedule is
        # the analytic form of the fused-kernel argument, next to the
        # exchange-locality one
        hbm = {ph: sum(r["local_hbm_bytes"]
                       for r in exchange_schedule(n, sizes, pol,
                                                  local_phase=ph))
               for ph in ("pallas", "reference")}
        print(f"engine_{tag}_{pol.name},{t:.0f},"
              f"inter_total={inter};intra_total={intra};"
              f"local_hbm_pallas={hbm['pallas']};"
              f"local_hbm_reference={hbm['reference']};n={n}")
        for k, r in enumerate(sched):
            print(f"engine_{tag}_{pol.name}_x{k},,"
                  f"level={r['level']};op={r['op']};"
                  f"inter={r['inter_pod_bytes']};intra={r['intra_pod_bytes']};"
                  f"hbm={r['local_hbm_bytes']};"
                  f"elems={r['local_merge_elems']}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=BACKENDS + ("both",),
                    default="constraint")
    ap.add_argument("--local-sort", choices=("jnp", "bitonic"), default=None,
                    help="engine leaf sort (bitonic = Pallas kernel; default "
                         "jnp, or bitonic under --local-phase pallas)")
    ap.add_argument("--local-phase", choices=("auto", "pallas", "reference"),
                    default="auto",
                    help="engine local-phase implementation (auto follows "
                         "--local-sort)")
    ap.add_argument("--logn", type=int, default=21,
                    help="log2 input size (2M int32 default, scaled from the "
                         "paper's 100M for the CPU harness)")
    ap.add_argument("--cases", type=lambda s: {int(c) for c in s.split(",")},
                    default=None, help="comma list of Table-1 cases to run")
    ap.add_argument("--pods", default=None, metavar="PxD[xM]",
                    help="run the hierarchical multi-pod engine grid on an "
                         "emulated (pod, data, model) mesh instead")
    args = ap.parse_args(argv)
    local_phase = None if args.local_phase == "auto" else args.local_phase
    if args.local_sort == "jnp" and local_phase == "pallas":
        raise SystemExit("--local-sort jnp conflicts with --local-phase "
                         "pallas: the fused kernel has no callable leaf sort")
    if args.local_sort is None:         # default leaf follows the phase
        local_sort = "bitonic" if local_phase == "pallas" else jnp.sort
    else:
        local_sort = jnp.sort if args.local_sort == "jnp" else "bitonic"
    if args.pods:
        print("name,us_per_call,derived")
        run_pods(args.pods, args.logn, local_sort, local_phase)
        return
    n = 1 << args.logn
    n_dev = len(jax.devices())
    locale = Locale.auto()
    print("name,us_per_call,derived")
    # the paper's normalisation: 1 worker, default policy — one shared
    # baseline (the engine is per-device, so it has no 1-worker mode)
    base_fn = locale.with_policy(
        LocalisationPolicy(False, False, Homing.HASH_INTERLEAVED)).workload(
            "sort", num_workers=1)
    t_base = timeit(lambda: base_fn(fresh(n)))
    print(f"sort_n{n}_case0_1worker_baseline,{t_base:.0f},speedup=1.00")
    backends = BACKENDS if args.backend == "both" else (args.backend,)
    for backend in backends:
        run_grid(locale, n_dev, backend,
                 local_sort if backend == "shard_map" else None, t_base,
                 n, args.cases, local_phase)


if __name__ == "__main__":
    main()
