"""Home-aware serving benchmark: fifo vs homed on an open-loop stream.

Drives the decode server (`repro.runtime.server`) with a synthetic
open-loop request stream — mixed prompt/output lengths (bimodal
short/long decodes), bursty arrivals (slot-sized groups landing
together), skewed session affinity (zipf-ish recurring sessions) — once
per scheduling policy, on the same model/params/mesh, and reports:

  serve_<policy>_<mesh>           us per generated token (wall clock) +
                                  tok/s, served, deterministic step count,
                                  waves, slot utilisation
  serve_<policy>_<mesh>_wait      p50/p99 admission wait (wave-step units,
                                  deterministic — structure row, no us)
  serve_<policy>_<mesh>_relayout  cross-home session-cache relayout bytes,
                                  split inter-pod/intra-pod on pod meshes,
                                  plus the homed scheduler's affinity hits
  serve_check_<mesh>              the acceptance facts: decode outputs
                                  bit-identical across policies, homed
                                  moved strictly fewer cross-home bytes,
                                  homed took no more deterministic steps

Every row family is emitted twice: once for the classic mixed stream and
once with a ``_prefix`` suffix for the *shared-prefix* stream — zipf-skewed
sessions whose requests all open with that session's sticky prompt prefix.
The prefix rows are the paged-KV acceptance stream: every row carries the
pool's reuse stats (``pages``, ``hits_full``/``hits_part``,
``rows_saved`` = prefill row-equivalents skipped by attaching pooled
pages instead of recomputing them), and `compare.py` gates the homed
``_prefix`` tok/s against the committed baseline.

Decode outputs are bit-identical across policies because the server pads
every prefill to the fixed ``--prompt-pad`` bucket (row numerics never
depend on wave composition), so every delta is pure scheduling.

Run under ``benchmarks/run.py`` (8 placeholder host devices, flat and
``--pods 2x2x2`` emulated-pod meshes) to produce `BENCH_serve.json`.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax

from repro.configs import get_config, reduce_config
from repro.models.model import LM
from repro.obs import NULL_TRACER, Tracer
from repro.obs import metrics as obs_metrics
from repro.runtime.server import DecodeServer, Request


def make_stream(cfg, n: int, slots: int, prompt_pad: int, sessions: int,
                short_new: int, long_new: int, seed: int):
    """Open-loop stream: bursty, bimodal lengths, zipf-skewed sessions."""
    rng = np.random.RandomState(seed)
    weights = 1.0 / (1.0 + np.arange(sessions))
    weights /= weights.sum()
    reqs = []
    for rid in range(n):
        plen = int(rng.randint(2, prompt_pad + 1))
        reqs.append(Request(
            rid=rid,
            prompt=rng.randint(0, cfg.vocab_size, plen).astype(np.int32),
            max_new=int(long_new if rng.rand() < 0.3 else short_new),
            session=f"s{rng.choice(sessions, p=weights)}",
            t_arrive=float(rid // (2 * slots)) * (prompt_pad + short_new)))
    return reqs


def make_prefix_stream(cfg, n: int, slots: int, prompt_pad: int,
                       sessions: int, short_new: int, long_new: int,
                       seed: int):
    """Shared-prefix stream: every request of a session opens with that
    session's sticky prompt prefix (half the pad bucket), followed by a
    fresh suffix — the KV prefix-reuse acceptance stream."""
    rng = np.random.RandomState(seed + 1)
    weights = 1.0 / (1.0 + np.arange(sessions))
    weights /= weights.sum()
    prefix_len = max(1, prompt_pad // 2)
    prefixes = rng.randint(0, cfg.vocab_size,
                           (sessions, prefix_len)).astype(np.int32)
    reqs = []
    for rid in range(n):
        sess = int(rng.choice(sessions, p=weights))
        slen = int(rng.randint(1, prompt_pad - prefix_len + 1))
        suffix = rng.randint(0, cfg.vocab_size, slen).astype(np.int32)
        reqs.append(Request(
            rid=rid,
            prompt=np.concatenate([prefixes[sess], suffix]),
            max_new=int(long_new if rng.rand() < 0.3 else short_new),
            session=f"s{sess}",
            t_arrive=float(rid // (2 * slots)) * (prompt_pad + short_new)))
    return reqs


def mesh_tag(pods, n_dev: int) -> str:
    return (f"pods{'x'.join(map(str, pods))}" if pods else f"flat{n_dev}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    from repro.launch.serve import build_plan, parse_pods
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--pods", type=parse_pods, default=None,
                    metavar="PxD[xM]", help="emulated-pod serving mesh")
    ap.add_argument("--slots", type=int, default=16)
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--prompt-pad", type=int, default=8)
    ap.add_argument("--sessions", type=int, default=6)
    ap.add_argument("--short-new", type=int, default=4)
    ap.add_argument("--long-new", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reps", type=int, default=3,
                    help="serve the stream N times, report best wall clock "
                    "(scheduling is deterministic — every rep is identical)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="also run ONE extra untimed traced rep per "
                    "policy x stream, streaming a JSONL trace here — the "
                    "timed reps keep the NullTracer, so numbers are "
                    "unaffected")
    args = ap.parse_args(argv)

    pods = args.pods
    cfg = reduce_config(get_config(args.arch))
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    plan = build_plan(pods, args.slots, args.max_len, cfg)
    tag = mesh_tag(pods, len(jax.devices()))
    tracer = (Tracer(args.trace, tool="bench_serve", tag=tag)
              if args.trace else None)

    print("name,us_per_call,derived")
    streams = (("", make_stream), ("_prefix", make_prefix_stream))
    outs = {lbl: {} for lbl, _ in streams}
    stats = {lbl: {} for lbl, _ in streams}
    rows_saved = {lbl: {} for lbl, _ in streams}
    for policy in ("fifo", "homed"):
        srv = DecodeServer(cfg, params, batch_slots=args.slots,
                           max_len=args.max_len, plan=plan,
                           scheduler=policy, prompt_pad=args.prompt_pad)
        # warm the jit caches (prefill + decode shapes are wave-invariant
        # thanks to the fixed pad bucket), then measure with fresh stats —
        # the wall clock is steady-state serving, not XLA compile time
        srv.submit(Request(rid=-1, prompt=np.asarray([1, 2], np.int32),
                           max_new=2))
        srv.run()
        from repro.runtime.scheduler import make_scheduler
        page_kw = dict(page_size=srv.scheduler.page_size,
                       page_capacity=srv.scheduler.page_capacity)
        for lbl, mk in streams:
            wall_us = float("inf")
            for _ in range(max(1, args.reps)):  # best-of-reps: identical
                srv.scheduler = make_scheduler(  # deterministic reps, min wall
                    policy, n_slots=srv.B, locale=srv.locale, cfg=cfg,
                    prompt_pad=args.prompt_pad, **page_kw)
                srv.store.clear()   # pool accounting restarts: content too
                for r in mk(cfg, args.requests, args.slots,
                            args.prompt_pad, args.sessions,
                            args.short_new, args.long_new, args.seed):
                    r.out, r.done, r.home = [], False, None
                    srv.submit(r)
                t0 = time.perf_counter()
                served = srv.run()
                wall_us = min(wall_us, (time.perf_counter() - t0) * 1e6)
            s = srv.scheduler.stats
            outs[lbl][policy] = {r.rid: tuple(r.out) for r in served}
            stats[lbl][policy] = s
            rows_saved[lbl][policy] = srv.scheduler.prefill_rows_saved()
            name = f"serve_{policy}_{tag}{lbl}"
            # ONE rendering path: these are the same numbers the launcher
            # prints and the trace's sched.summary event carries
            for row in obs_metrics.bench_rows(
                    name, srv.scheduler.summary(), wall_us):
                print(row)
            if tracer is not None:
                # one extra UNTIMED rep with the live tracer: identical
                # deterministic schedule, so the trace describes exactly
                # the run the rows above measured
                srv.scheduler = make_scheduler(
                    policy, n_slots=srv.B, locale=srv.locale, cfg=cfg,
                    prompt_pad=args.prompt_pad, tracer=tracer, **page_kw)
                srv.tracer = srv.store.tracer = tracer
                srv.store.clear()
                for r in mk(cfg, args.requests, args.slots,
                            args.prompt_pad, args.sessions,
                            args.short_new, args.long_new, args.seed):
                    r.out, r.done, r.home = [], False, None
                    srv.submit(r)
                srv.run()
                srv.scheduler.emit_summary()
                srv.tracer = srv.store.tracer = NULL_TRACER
    for lbl, _ in streams:
        o, st = outs[lbl], stats[lbl]
        identical = o["fifo"] == o["homed"]
        fewer = st["homed"].relayout_bytes < st["fifo"].relayout_bytes
        no_slower = st["homed"].steps <= st["fifo"].steps
        print(f"serve_check_{tag}{lbl},,bit_identical={identical};"
              f"relayout_homed_lt_fifo={fewer};"
              f"steps_homed_le_fifo={no_slower};"
              f"rows_saved_homed={rows_saved[lbl]['homed']:.1f}")
    if tracer is not None:
        tracer.close()


if __name__ == "__main__":
    main()
