"""Paper Fig. 4 — memory-striping analogue: source-width of the fetch phase.

TILEPro64 striping spreads pages over 1-4 memory controllers. The pod
analogue: the workers' chunk-fill (localise) pulls from an input striped
over `width` source devices — width 1 is the single-controller hot spot,
width 8 is fully striped. We time the reshard itself (the memory-fetch
phase); the compute phase is locality-cached and unaffected, matching the
paper's conclusion that striping is transparent once caching is on.

Both the striped source and the chunk-fill target are `Locale`s: the fetch
is literally `target_locale.put(...)`.

The ``--pipeline`` section is the acceptance benchmark for the *generation*
half of striping (the ROADMAP's remaining item): `data.SyntheticLM` with
``striped=True`` generates each batch stripe for its home device
(per-device callbacks under `Locale.make`), vs the ``striped=False`` oracle
that builds the full host array first and places it afterwards.  The
embedding family is where striping pays most — the host oracle materialises
the whole ``(B, S, D)`` array before a single byte reaches a device.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core import Locale
from benchmarks.common import timeit


def bench_pipeline(logb: int):
    """striped vs host-built batch generation, token + embedding families."""
    from repro.configs import get_config, reduce_config
    from repro.data import SyntheticLM

    B = 1 << logb
    mesh = (jax.make_mesh((len(jax.devices()),), ("data",))
            if len(jax.devices()) > 1 else None)
    cases = [("tokens", reduce_config(get_config("qwen3-0.6b")), 128),
             ("embeds", reduce_config(get_config("musicgen-medium")), 128)]
    for label, cfg, S in cases:
        for striped in (False, True):
            ds = SyntheticLM(cfg, B, S, seed=3, mesh=mesh, striped=striped)
            step = [0]

            def make_batch():
                step[0] += 1           # fresh step: no row-cache reuse
                return jax.block_until_ready(
                    jax.tree.leaves(ds.batch(step[0])))

            t = timeit(make_batch, warmup=1, iters=3)
            mode = "striped" if striped else "host"
            print(f"striping_pipeline_{label}_{mode},{t:.0f},"
                  f"B{B}_S{S}_born_on_{'chunk' if striped else 'host'}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--logn", type=int, default=22)
    ap.add_argument("--logb", type=int, default=9,
                    help="log2 global batch for the --pipeline section")
    ap.add_argument("--pipeline", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="run the striped-generation acceptance section")
    args = ap.parse_args(argv)
    n = 1 << args.logn
    devs = jax.devices()
    n_dev = len(devs)
    print("name,us_per_call,derived")
    if n_dev == 1:
        print("striping_skipped,,single_device")
        return
    target = Locale.auto()
    for w in dict.fromkeys(w for w in (1, 2, 4, n_dev) if w <= n_dev):
        src = Locale.auto(devices=devs[:w])

        def make():
            placed = src.put(jnp.arange(n, dtype=jnp.int32))
            return placed.data

        def fetch(x):
            return target.put(x).data   # workers fill their chunks

        x = make()
        t = timeit(lambda: fetch(x), warmup=1, iters=3)
        print(f"striping_width{w},{t:.0f},fetch_from_{w}_controllers")
    if args.pipeline:
        bench_pipeline(args.logb)


if __name__ == "__main__":
    main()
