"""Paper Fig. 4 — memory-striping analogue: source-width of the fetch phase.

TILEPro64 striping spreads pages over 1-4 memory controllers. The pod
analogue: the workers' chunk-fill (localise) pulls from an input striped
over `width` source devices — width 1 is the single-controller hot spot,
width 8 is fully striped. We time the reshard itself (the memory-fetch
phase); the compute phase is locality-cached and unaffected, matching the
paper's conclusion that striping is transparent once caching is on.

Both the striped source and the chunk-fill target are `Locale`s: the fetch
is literally `target_locale.put(...)`.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core import Locale
from benchmarks.common import timeit


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--logn", type=int, default=22)
    args = ap.parse_args(argv)
    n = 1 << args.logn
    devs = jax.devices()
    n_dev = len(devs)
    print("name,us_per_call,derived")
    if n_dev == 1:
        print("striping_skipped,,single_device")
        return
    target = Locale.auto()
    for w in dict.fromkeys(w for w in (1, 2, 4, n_dev) if w <= n_dev):
        src = Locale.auto(devices=devs[:w])

        def make():
            placed = src.put(jnp.arange(n, dtype=jnp.int32))
            return placed.data

        def fetch(x):
            return target.put(x).data   # workers fill their chunks

        x = make()
        t = timeit(lambda: fetch(x), warmup=1, iters=3)
        print(f"striping_width{w},{t:.0f},fetch_from_{w}_controllers")


if __name__ == "__main__":
    main()
