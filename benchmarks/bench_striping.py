"""Paper Fig. 4 — memory-striping analogue: source-width of the fetch phase.

TILEPro64 striping spreads pages over 1-4 memory controllers. The pod
analogue: the workers' chunk-fill (localise) pulls from an input striped
over `width` source devices — width 1 is the single-controller hot spot,
width 8 is fully striped. We time the reshard itself (the memory-fetch
phase); the compute phase is locality-cached and unaffected, matching the
paper's conclusion that striping is transparent once caching is on.
"""
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from benchmarks.common import timeit

N = 1 << 22


def main():
    devs = jax.devices()
    n_dev = len(devs)
    print("name,us_per_call,derived")
    if n_dev == 1:
        print("striping_skipped,,single_device")
        return
    mesh = jax.make_mesh((n_dev,), ("data",))
    target = NamedSharding(mesh, P("data"))
    for w in [w for w in (1, 2, 4, n_dev) if w <= n_dev]:
        sub = jax.make_mesh((w,), ("data",), devices=devs[:w])
        src = NamedSharding(sub, P("data"))

        def make():
            return jax.device_put(
                jnp.arange(N, dtype=jnp.int32), src)

        def fetch(x):
            return jax.device_put(x, target)   # workers fill their chunks

        x = make()
        t = timeit(lambda: fetch(x), warmup=1, iters=3)
        print(f"striping_width{w},{t:.0f},fetch_from_{w}_controllers")


if __name__ == "__main__":
    main()
