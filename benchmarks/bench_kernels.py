"""Kernel-level localisation (Fig-1, TPU-native): VMEM reuse arithmetic.

interpret-mode wall times are Python emulation (not TPU perf) — the honest
derived metric is the HBM-traffic ratio: the localised kernel reads+writes
each chunk once regardless of R, the non-localised path streams the full
array every pass. derived = modelled HBM-bytes ratio (== Fig-1 asymptote).
"""
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from benchmarks.common import timeit

CHUNKS, L = 8, 2048


def main():
    print("name,us_per_call,derived")
    x = jax.random.normal(jax.random.key(0), (CHUNKS, L), jnp.float32)
    for reps in (8, 64):
        t_loc = timeit(lambda: ops.localised_copy(x, reps))
        t_ref = timeit(lambda: jax.jit(
            lambda y: ref.localised_copy_ref(y, reps))(x))
        bytes_localised = 2 * x.size * 4                 # one read + one write
        bytes_streamed = 2 * x.size * 4 * reps           # per-pass streaming
        print(f"kernel_localised_copy_reps{reps},{t_loc:.0f},"
              f"hbm_ratio={bytes_streamed / bytes_localised:.0f}x")
        print(f"kernel_streaming_ref_reps{reps},{t_ref:.0f},")
    # flash attention: VMEM-blocked vs dense-materialised scores
    B, H, S, hd = 1, 4, 1024, 64
    q = jax.random.normal(jax.random.key(1), (B, H, S, hd), jnp.bfloat16)
    k = jax.random.normal(jax.random.key(2), (B, H, S, hd), jnp.bfloat16)
    v = jax.random.normal(jax.random.key(3), (B, H, S, hd), jnp.bfloat16)
    t_flash = timeit(lambda: ops.flash_attention(q, k, v, causal=True),
                     iters=1)
    t_dense = timeit(lambda: jax.jit(
        lambda a, b, c: ref.attention_ref(a, b, c, causal=True))(q, k, v))
    dense_hbm = B * H * S * S * 4 * 2          # scores materialised r+w (f32)
    flash_hbm = 3 * B * H * S * hd * 2 + B * H * S * hd * 2
    print(f"kernel_flash_attention_s{S},{t_flash:.0f},"
          f"score_hbm_saved={dense_hbm / flash_hbm:.1f}x")
    print(f"kernel_dense_attention_s{S},{t_dense:.0f},")
    # bitonic local sort
    xs = jax.random.randint(jax.random.key(4), (8, 1024), 0, 1 << 30,
                            dtype=jnp.int32)
    t_bit = timeit(lambda: ops.bitonic_sort(xs), iters=1)
    t_ref = timeit(lambda: jax.jit(ref.sort_ref)(xs))
    print(f"kernel_bitonic_sort_8x1024,{t_bit:.0f},interpret_mode=true")
    print(f"kernel_jnp_sort_8x1024,{t_ref:.0f},")


if __name__ == "__main__":
    main()
