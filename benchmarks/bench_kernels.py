"""Kernel-level localisation (Fig-1, TPU-native): VMEM reuse arithmetic.

interpret-mode wall times are Python emulation (not TPU perf) — the honest
derived metric is the HBM-traffic ratio: the localised kernel reads+writes
each chunk once regardless of R, the non-localised path streams the full
array every pass. derived = modelled HBM-bytes ratio (== Fig-1 asymptote).

The ``local``/``merge`` sections benchmark the engine's VMEM-resident local
phase (the sort's own Fig-1 argument):

  * ``kernel_local_*`` — leaf-sort-only kernel vs the FUSED local_sort
    kernel (leaves + whole merge tree in one VMEM pass) vs the reference
    jnp local phase (leaf kernel + HBM-materialising vmapped rank merges),
    swept over chunk sizes.  derived = modelled HBM bytes ratio
    (reference streams the chunk once per tree level, fused touches it
    once: ratio = 1 + log2(leaves)).
  * ``kernel_merge_*`` — the merge-path merge_split kernel (computes ONLY
    the kept half) vs merge-everything-discard-half.  derived = modelled
    HBM ratio 7/3 and merged-elems ratio 2.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core.sort import merge_sorted
from repro.kernels import ops, ref
from benchmarks.common import timeit

CHUNKS, L = 8, 2048
SECTIONS = ("copy", "attention", "sort", "local", "merge")

_merge_rows = jax.vmap(merge_sorted)


def bench_copy():
    x = jax.random.normal(jax.random.key(0), (CHUNKS, L), jnp.float32)
    for reps in (8, 64):
        t_loc = timeit(lambda: ops.localised_copy(x, reps))
        t_ref = timeit(lambda: jax.jit(
            lambda y: ref.localised_copy_ref(y, reps))(x))
        bytes_localised = 2 * x.size * 4                 # one read + one write
        bytes_streamed = 2 * x.size * 4 * reps           # per-pass streaming
        print(f"kernel_localised_copy_reps{reps},{t_loc:.0f},"
              f"hbm_ratio={bytes_streamed / bytes_localised:.0f}x")
        print(f"kernel_streaming_ref_reps{reps},{t_ref:.0f},")


def bench_attention():
    # flash attention: VMEM-blocked vs dense-materialised scores
    B, H, S, hd = 1, 4, 1024, 64
    q = jax.random.normal(jax.random.key(1), (B, H, S, hd), jnp.bfloat16)
    k = jax.random.normal(jax.random.key(2), (B, H, S, hd), jnp.bfloat16)
    v = jax.random.normal(jax.random.key(3), (B, H, S, hd), jnp.bfloat16)
    t_flash = timeit(lambda: ops.flash_attention(q, k, v, causal=True),
                     iters=1)
    t_dense = timeit(lambda: jax.jit(
        lambda a, b, c: ref.attention_ref(a, b, c, causal=True))(q, k, v))
    dense_hbm = B * H * S * S * 4 * 2          # scores materialised r+w (f32)
    flash_hbm = 3 * B * H * S * hd * 2 + B * H * S * hd * 2
    print(f"kernel_flash_attention_s{S},{t_flash:.0f},"
          f"score_hbm_saved={dense_hbm / flash_hbm:.1f}x")
    print(f"kernel_dense_attention_s{S},{t_dense:.0f},")


def bench_sort():
    # bitonic local sort (leaf kernel alone, the pre-fusion baseline)
    xs = jax.random.randint(jax.random.key(4), (8, 1024), 0, 1 << 30,
                            dtype=jnp.int32)
    t_bit = timeit(lambda: ops.bitonic_sort(xs), iters=1)
    t_ref = timeit(lambda: jax.jit(ref.sort_ref)(xs))
    print(f"kernel_bitonic_sort_8x1024,{t_bit:.0f},interpret_mode=true")
    print(f"kernel_jnp_sort_8x1024,{t_ref:.0f},")


def bench_local(chunks: int, logcs, leaves: int):
    """Fused VMEM-resident local phase vs leaf-only vs reference jnp tree."""
    for logc in logcs:
        C = 1 << logc
        leaf = max(1, C // leaves)
        w = C // leaf                               # leaves per chunk
        x = jax.random.randint(jax.random.key(5), (chunks, C), 0, 1 << 30,
                               dtype=jnp.int32)

        @jax.jit
        def reference(y):
            # today's engine reference path: Pallas leaf sort, then the
            # HBM-materialising Python merge-tree of vmapped rank merges
            runs = ops.bitonic_sort(y.reshape(chunks * w, leaf))
            runs = runs.reshape(chunks, w, leaf)
            while runs.shape[1] > 1:
                runs = jax.vmap(_merge_rows)(runs[:, 0::2], runs[:, 1::2])
            return runs.reshape(chunks, C)

        # interpret-mode wall clocks are noisy at small chunks: best-of-10
        t_leaf = timeit(lambda: ops.bitonic_sort(x.reshape(chunks * w, leaf)),
                        iters=10)
        t_fused = timeit(lambda: ops.local_sort(x), iters=10)
        t_ref = timeit(lambda: reference(x), iters=10)
        hbm_fused = 2 * chunks * C * 4              # one VMEM round trip
        hbm_ref = hbm_fused * (1 + max(0, w.bit_length() - 1))
        print(f"kernel_local_leaf_only_c{C},{t_leaf:.0f},leaf={leaf}")
        print(f"kernel_local_fused_c{C},{t_fused:.0f},"
              f"hbm_saved={hbm_ref / hbm_fused:.0f}x;"
              f"speedup={t_ref / max(t_fused, 1e-9):.2f}")
        print(f"kernel_local_reference_c{C},{t_ref:.0f},"
              f"tree_levels={w.bit_length() - 1}")


def bench_merge(chunks: int, logcs):
    """merge-path merge_split (kept half only) vs merge-and-discard-half."""
    keep = (jnp.arange(chunks) % 2) == 0
    for logc in logcs:
        C = 1 << logc
        a = jnp.sort(jax.random.randint(jax.random.key(6), (chunks, C), 0,
                                        1 << 30, dtype=jnp.int32), axis=-1)
        b = jnp.sort(jax.random.randint(jax.random.key(7), (chunks, C), 0,
                                        1 << 30, dtype=jnp.int32), axis=-1)

        @jax.jit
        def discard_half(u, v, k):
            merged = _merge_rows(u, v)              # (chunks, 2C) to HBM
            return jnp.where(k[:, None], merged[:, :C], merged[:, C:])

        t_split = timeit(lambda: ops.merge_split(a, b, keep), iters=10)
        t_full = timeit(lambda: discard_half(a, b, keep), iters=10)
        print(f"kernel_merge_split_c{C},{t_split:.0f},"
              f"hbm_saved={7 / 3:.2f}x;elems_saved=2x;"
              f"speedup={t_full / max(t_split, 1e-9):.2f}")
        print(f"kernel_merge_discard_c{C},{t_full:.0f},")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help=f"comma list of sections to run ({','.join(SECTIONS)})")
    ap.add_argument("--chunks", type=int, default=8,
                    help="rows (device chunks) per local/merge case")
    ap.add_argument("--logcs", type=lambda s: [int(c) for c in s.split(",")],
                    default=[10, 12, 14],
                    help="comma list of log2 chunk sizes for local/merge")
    ap.add_argument("--leaves", type=int, default=8,
                    help="leaves per chunk in the local-phase cases")
    args = ap.parse_args(argv)
    only = set((args.only or ",".join(SECTIONS)).split(","))
    unknown = only - set(SECTIONS)
    if unknown:
        raise SystemExit(f"unknown sections {sorted(unknown)}; "
                         f"want a subset of {SECTIONS}")
    print("name,us_per_call,derived")
    if "copy" in only:
        bench_copy()
    if "attention" in only:
        bench_attention()
    if "sort" in only:
        bench_sort()
    if "local" in only:
        bench_local(args.chunks, args.logcs, args.leaves)
    if "merge" in only:
        bench_merge(args.chunks, args.logcs)


if __name__ == "__main__":
    main()
