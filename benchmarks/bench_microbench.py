"""Paper Fig. 1 — micro-benchmark: localised vs non-localised repetitive copy.

1M-element array (paper size), 8 workers, growing repetition counts.
`derived` = non-localised / localised wall-time ratio (the Fig-1 gap, which
should grow with the number of repeated accesses).
"""
import jax
import jax.numpy as jnp

from repro.core import Homing, LocalisationPolicy
from repro.core.microbench import make_microbench_fn
from benchmarks.common import timeit

N = 1_000_000


def main():
    mesh = (jax.make_mesh((len(jax.devices()),), ("data",))
            if len(jax.devices()) > 1 else None)
    loc = LocalisationPolicy(localised=True, static_mapping=True,
                             homing=Homing.LOCAL_CHUNKED)
    nonloc = LocalisationPolicy(localised=False, static_mapping=True,
                                homing=Homing.HASH_INTERLEAVED)
    print("name,us_per_call,derived")
    for reps in (8, 32, 128):
        x = jnp.arange(N, dtype=jnp.float32)
        f_loc = make_microbench_fn(mesh, loc, reps)
        f_non = make_microbench_fn(mesh, nonloc, reps)
        t_loc = timeit(lambda: f_loc(jnp.arange(N, dtype=jnp.float32)))
        t_non = timeit(lambda: f_non(jnp.arange(N, dtype=jnp.float32)))
        print(f"microbench_localised_reps{reps},{t_loc:.0f},")
        print(f"microbench_nonlocalised_reps{reps},{t_non:.0f},"
              f"gap={t_non / max(t_loc, 1e-9):.2f}x")


if __name__ == "__main__":
    main()
