"""Paper Fig. 1 — micro-benchmark: localised vs non-localised repetitive copy.

1M-element array (paper size), 8 workers, growing repetition counts.
`derived` = non-localised / localised wall-time ratio (the Fig-1 gap, which
should grow with the number of repeated accesses).  Both variants are built
with ``Locale.workload("microbench", reps=R)``.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core import Homing, Locale, LocalisationPolicy
from benchmarks.common import timeit


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1_000_000)
    ap.add_argument("--reps", type=lambda s: [int(v) for v in s.split(",")],
                    default=[8, 32, 128], help="comma list of pass counts")
    args = ap.parse_args(argv)
    n = args.n
    locale = Locale.auto()
    loc = locale.with_policy(LocalisationPolicy(
        localised=True, static_mapping=True, homing=Homing.LOCAL_CHUNKED))
    nonloc = locale.with_policy(LocalisationPolicy(
        localised=False, static_mapping=True, homing=Homing.HASH_INTERLEAVED))
    print("name,us_per_call,derived")
    for reps in args.reps:
        f_loc = loc.workload("microbench", reps=reps)
        f_non = nonloc.workload("microbench", reps=reps)
        t_loc = timeit(lambda: f_loc(jnp.arange(n, dtype=jnp.float32)))
        t_non = timeit(lambda: f_non(jnp.arange(n, dtype=jnp.float32)))
        print(f"microbench_localised_reps{reps},{t_loc:.0f},")
        print(f"microbench_nonlocalised_reps{reps},{t_non:.0f},"
              f"gap={t_non / max(t_loc, 1e-9):.2f}x")


if __name__ == "__main__":
    main()
