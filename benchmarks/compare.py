"""Diff two ``BENCH_*.json`` files — the CI-ready perf regression gate.

``python -m benchmarks.compare BASE.json NEW.json [--fail-above PCT]``

Prints one CSV row per case present in both files with the wall-clock
delta (positive = NEW is slower = regression) and the speedup factor, a
``# only-in-...`` comment line per case that appears in exactly one file
(renamed/dropped benches never vanish silently), and a summary line.  With
``--fail-above PCT`` the exit code is 1 when any case regresses by more
than PCT percent — wire it between a committed baseline and a fresh
``benchmarks/run.py`` run to gate a PR.

Structure-only records (``us == null``: HLO byte counts, exchange-schedule
rows, serving wait/relayout rows) carry no wall-clock and are skipped.

When both runs were produced with ``run.py --check``, the static verdicts
are gated too: a case whose baseline record says ``"homecheck": "clean"``
but whose new record says ``"findings:N"`` (or ``"failed"``) fails the
compare regardless of wall-clock — a locality regression is a regression
even when it happens to be fast.  The ``"ci_gate"`` verdict stamped by
``benchmarks/ci_gate.sh`` (fast tests + the full R1-R8 analyzer sweep) is
gated the same way: baseline ``"pass"`` -> new anything else fails, and so
is the ``"schedcheck"`` R9 scheduler certificate on the serving families
(baseline ``"certified"`` -> new ``"findings:N"`` fails).
Records without a field (old baselines, runs without ``--check`` or the
gate) are not gated.

Serving throughput is gated the same way: ``BENCH_serve.json``'s timed
``serve_<policy>_<mesh>`` rows store *us per generated token*, so "NEW is
slower" means fewer tokens per second and ``--fail-above`` catches a
serving regression exactly like a sort one:

    python -m benchmarks.compare BENCH_serve.json /tmp/new/BENCH_serve.json \
        --fail-above 25

The ``_prefix`` family (the shared-prefix paged-KV acceptance stream) is
additionally gated on its *derived* fields: a ``tok_s`` drop beyond
``--fail-above`` fails even if the us-per-token row is missing, and a
baseline with ``rows_saved > 0`` whose candidate stops attaching pages
(``rows_saved == 0``) fails outright — losing prefix reuse is a
regression even at equal wall-clock.

The ``_wait`` rows (p50/p99 admission wait in deterministic wave-step
units) are gated with the same ``--fail-above`` threshold: a scheduling
change that makes requests queue longer fails even when the wall clock
is unchanged.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List


def load(path: str) -> Dict[str, float]:
    """name -> us for every timed record (structure-only rows dropped)."""
    with open(path) as f:
        records = json.load(f)
    return {r["name"]: r["us"] for r in records if r.get("us") is not None}


#: verdict fields gated by the compare: field -> the passing value.
#: "schedcheck" is the R9 scheduler certificate run.py --check stamps on
#: the serving families — a certified -> findings flip fails the compare.
VERDICT_KEYS = {"homecheck": "clean", "ci_gate": "pass",
                "schedcheck": "certified"}


def load_checks(path: str, key: str = "homecheck") -> Dict[str, str]:
    """name -> verdict for records stamped with `key` (run.py --check
    stamps "homecheck", benchmarks/ci_gate.sh stamps "ci_gate")."""
    with open(path) as f:
        records = json.load(f)
    return {r["name"]: r[key] for r in records if key in r}


def check_regressions(base_chk: Dict[str, str], new_chk: Dict[str, str],
                      ok: str = "clean") -> Dict[str, str]:
    """Cases whose verdict was `ok` in base but is not in new."""
    return {n: new_chk[n] for n in sorted(base_chk.keys() & new_chk.keys())
            if base_chk[n] == ok and new_chk[n] != ok}


def load_derived(path: str) -> Dict[str, Dict[str, float]]:
    """name -> numeric derived fields (``k=v`` pairs, ``;``-separated)."""
    with open(path) as f:
        records = json.load(f)
    out: Dict[str, Dict[str, float]] = {}
    for r in records:
        fields = {}
        for kv in (r.get("derived") or "").split(";"):
            k, _, v = kv.partition("=")
            try:
                fields[k] = float(v)
            except ValueError:
                continue
        if fields:
            out[r["name"]] = fields
    return out


def prefix_regressions(base: Dict[str, Dict[str, float]],
                       new: Dict[str, Dict[str, float]],
                       fail_above: float = None) -> List[str]:
    """Derived-field gate for the ``_prefix`` serving family: tok/s drops
    beyond `fail_above` percent and vanished prefix reuse both fail."""
    bad = []
    for name in sorted(base.keys() & new.keys()):
        if "_prefix" not in name:
            continue
        b, n = base[name], new[name]
        if fail_above is not None and b.get("tok_s") and "tok_s" in n:
            drop = (b["tok_s"] - n["tok_s"]) / b["tok_s"] * 100.0
            if drop > fail_above:
                bad.append(f"{name}: tok_s {b['tok_s']:.0f} -> "
                           f"{n['tok_s']:.0f} ({drop:+.1f}%)")
        for key in ("rows_saved", "rows_saved_homed"):
            if b.get(key, 0.0) > 0.0 and n.get(key) == 0.0:
                bad.append(f"{name}: {key} {b[key]:.1f} -> 0 "
                           f"(prefix reuse vanished)")
    return bad


def wait_regressions(base: Dict[str, Dict[str, float]],
                     new: Dict[str, Dict[str, float]],
                     fail_above: float = None) -> List[str]:
    """Latency gate for the ``_wait`` serving rows: a p50/p99 admission-
    wait increase beyond `fail_above` percent fails (wave-step units are
    deterministic, so this is a pure scheduling regression, invisible to
    the wall-clock gate).  A baseline of 0 only gates going nonzero."""
    if fail_above is None:
        return []
    bad = []
    for name in sorted(base.keys() & new.keys()):
        if not name.endswith("_wait"):
            continue
        b, n = base[name], new[name]
        for key in ("p50", "p99"):
            if key not in b or key not in n:
                continue
            if b[key] > 0:
                rise = (n[key] - b[key]) / b[key] * 100.0
                if rise > fail_above:
                    bad.append(f"{name}: {key} {b[key]:.1f} -> {n[key]:.1f} "
                               f"({rise:+.1f}%)")
            elif n[key] > 0:
                bad.append(f"{name}: {key} 0 -> {n[key]:.1f} "
                           f"(waits appeared)")
    return bad


def compare(base: Dict[str, float], new: Dict[str, float]) -> List[Dict]:
    """Per-case rows, sorted worst regression first."""
    rows = []
    for name in base.keys() & new.keys():
        b, n = base[name], new[name]
        rows.append({
            "name": name, "base_us": b, "new_us": n,
            "delta_pct": (n - b) / b * 100.0 if b else float("inf"),
            "speedup": b / n if n else float("inf"),
        })
    return sorted(rows, key=lambda r: -r["delta_pct"])


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="diff two BENCH_*.json files; exit 1 on regression")
    ap.add_argument("base", help="baseline BENCH_*.json (e.g. committed)")
    ap.add_argument("new", help="candidate BENCH_*.json (e.g. fresh run)")
    ap.add_argument("--fail-above", type=float, default=None, metavar="PCT",
                    help="exit 1 if any case regresses by more than PCT%%")
    args = ap.parse_args(argv)
    base, new = load(args.base), load(args.new)
    rows = compare(base, new)
    print("name,base_us,new_us,delta_pct,speedup")
    for r in rows:
        print(f"{r['name']},{r['base_us']:.0f},{r['new_us']:.0f},"
              f"{r['delta_pct']:+.1f},{r['speedup']:.2f}x")
    for name in sorted(base.keys() - new.keys()):
        print(f"# only-in-base: {name}")
    for name in sorted(new.keys() - base.keys()):
        print(f"# only-in-new: {name}")
    rc = 0
    for key, ok in VERDICT_KEYS.items():
        dirty = check_regressions(load_checks(args.base, key),
                                  load_checks(args.new, key), ok=ok)
        for name, verdict in dirty.items():
            print(f"# {key}-regression: {name}: {ok} -> {verdict}",
                  file=sys.stderr)
        if dirty:
            print(f"# FAIL: {len(dirty)} previously {key}-{ok} case(s) "
                  f"regressed", file=sys.stderr)
            rc = 1
    base_d, new_d = load_derived(args.base), load_derived(args.new)
    prefix_bad = prefix_regressions(base_d, new_d,
                                    fail_above=args.fail_above)
    for msg in prefix_bad:
        print(f"# prefix-regression: {msg}", file=sys.stderr)
    if prefix_bad:
        print(f"# FAIL: {len(prefix_bad)} _prefix-family derived "
              f"regression(s)", file=sys.stderr)
        rc = 1
    wait_bad = wait_regressions(base_d, new_d, fail_above=args.fail_above)
    for msg in wait_bad:
        print(f"# wait-regression: {msg}", file=sys.stderr)
    if wait_bad:
        print(f"# FAIL: {len(wait_bad)} _wait-family latency "
              f"regression(s)", file=sys.stderr)
        rc = 1
    if not rows:
        print("# no common timed cases", file=sys.stderr)
        return rc or 2
    worst = rows[0]
    print(f"# {len(rows)} common cases; worst delta "
          f"{worst['delta_pct']:+.1f}% ({worst['name']})")
    if args.fail_above is not None and worst["delta_pct"] > args.fail_above:
        bad = [r["name"] for r in rows if r["delta_pct"] > args.fail_above]
        print(f"# FAIL: {len(bad)} case(s) regressed more than "
              f"{args.fail_above:.1f}%: {', '.join(bad)}", file=sys.stderr)
        rc = 1
    return rc


if __name__ == "__main__":
    sys.exit(main())
