"""Paper Fig. 3 — best cases across input sizes.

Cases 8 (fully localised, local homing) vs 3 (non-localised, hash) vs 7
(localised under hash): the localisation gap should grow with input size.
``--backend`` selects the constraint-hint tree or the shard_map engine;
``--logns`` the size sweep. Placement goes through `Locale`.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core import BACKENDS, Homing, Locale, LocalisationPolicy
from benchmarks.common import timeit

CASES = {
    "case8_loc_local": LocalisationPolicy(True, True, Homing.LOCAL_CHUNKED),
    "case7_loc_hash": LocalisationPolicy(True, True, Homing.HASH_INTERLEAVED),
    "case3_nonloc_hash": LocalisationPolicy(False, True,
                                            Homing.HASH_INTERLEAVED),
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=BACKENDS, default="constraint")
    ap.add_argument("--logns", type=lambda s: [int(v) for v in s.split(",")],
                    default=[18, 20, 22], help="comma list of log2 sizes")
    args = ap.parse_args(argv)
    n_dev = len(jax.devices())
    locale = Locale.auto()
    # engine on CPU: jnp leaf sort (the Pallas kernel only interprets here)
    local_sort = jnp.sort if args.backend == "shard_map" else None
    print("name,us_per_call,derived")
    for logn in args.logns:
        n = 1 << logn
        times = {}
        for name, pol in CASES.items():
            fn = locale.with_policy(pol).workload(
                "sort", backend=args.backend, local_sort=local_sort,
                num_workers=n_dev if n_dev > 1 else 8)
            times[name] = timeit(lambda: fn(jax.random.randint(
                jax.random.key(1), (n,), 0, 1 << 30, dtype=jnp.int32)))
            print(f"sort_{args.backend}_n{n}_{name},{times[name]:.0f},")
        gap = times["case3_nonloc_hash"] / max(times["case8_loc_local"], 1e-9)
        print(f"sort_{args.backend}_n{n}_fig3_gap,,case3/case8={gap:.2f}x")


if __name__ == "__main__":
    main()
