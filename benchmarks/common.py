"""Benchmark helpers: timing + subprocess-with-N-host-devices runner."""
from __future__ import annotations

import os
import subprocess
import sys
import time


def timeit(fn, *args, warmup: int = 1, iters: int = 3):
    """Best-of-iters wall time in microseconds (jit-compatible)."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def run_with_devices(module: str, n_devices: int = 8, timeout: int = 1200,
                     args=()):
    """Run `python -m benchmarks.<module>` with N host devices; relay stdout."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-m", f"benchmarks.{module}",
                        *args],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    if r.returncode != 0:
        print(f"# {module} FAILED:\n{r.stderr[-2000:]}", file=sys.stderr)
    return r.stdout
