#!/usr/bin/env bash
# Pre-merge gate: fast tests + the full static analyzer sweep, one command.
#
#   benchmarks/ci_gate.sh [BENCH_DIR]
#
# Runs `pytest -m "not slow"` and `launch/homecheck.py --workload all
# --rules all` over a flat and a hierarchical emulated mesh (the analyzer
# subprocesses set their own XLA_FLAGS).  `--rules all` is R1-R11: each
# sweep includes the R9 scheduler certificate over the full small-config
# lattice — now including the paged (page_capacity > 0) and continuous-
# refill configs, so I8 (page refcounts never leak) is part of the
# certificate — and the R10/R11 (HBM live-range, collective control
# flow) checks on every lowered workload.  A traced ``--smoke`` serve then
# runs with ``--trace`` and `launch/tracelog.py --validate` replays it,
# proving the observability counter identities (trace schema, charged
# bytes == scheduler stats == summary, pool refcounts balance, every
# off-home decode paid for).  A dedicated step then proves
# the certificate has teeth: every committed scheduler mutant (including
# `leak_page`, which drops a page-refcount release) must be *refuted*
# with a minimal witness tagged with its invariant — an R9 that stopped
# catching a known-bad scheduler fails the gate even though every clean
# sweep still passes.  It then stamps the combined verdict
# (`"ci_gate": "pass"|"fail"`) into every record of every BENCH_*.json in
# BENCH_DIR (default: repo root) alongside the existing "homecheck" key —
# `benchmarks/compare.py` fails a PR whose baseline was "pass" but whose
# fresh run is not.  Exit status 0 iff everything passed.
set -u
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
BENCH_DIR="${1:-.}"
verdict=pass

echo "== ci_gate: pytest -m 'not slow' =="
python -m pytest -x -q -m "not slow" || verdict=fail

echo "== ci_gate: homecheck --workload all --rules all (flat 1x8) =="
python -m repro.launch.homecheck --workload all --pods 1x8 \
    --policy all --rules all || verdict=fail

echo "== ci_gate: homecheck --workload all --rules all (hier 2x2x2) =="
python -m repro.launch.homecheck --workload all --pods 2x2x2 \
    --policy all --rules all || verdict=fail

echo "== ci_gate: traced smoke serve + trace reconciliation =="
TRACE="$(mktemp -t ci_trace.XXXXXX.jsonl)"
python -m repro.launch.serve --policy homed --smoke --trace "$TRACE" \
    > /dev/null || verdict=fail
# the validator replays the trace and proves every counter identity
# (charges == stats == summary bytes, pool refs balance, off-home decodes
# all paid for) — a broken instrumentation layer fails the gate here
python -m repro.launch.tracelog "$TRACE" --validate || verdict=fail
rm -f "$TRACE"

echo "== ci_gate: R9 mutant refutation (every committed mutant witnessed) =="
python - <<'EOF' || verdict=fail
from repro.analysis.fixtures import MUTANT_INVARIANT, mutant_scheduler
from repro.analysis.schedcheck import certify
from repro.runtime.scheduler import MUTATIONS

ok = True
for mutation in MUTATIONS:
    witness, states = certify(mutant_scheduler(mutation))
    if witness is None:
        print(f"R9 mutant NOT refuted: {mutation} certified clean over "
              f"{states} states — the certificate lost its teeth")
        ok = False
    elif witness.invariant != MUTANT_INVARIANT[mutation]:
        print(f"R9 mutant {mutation}: wrong invariant "
              f"{witness.invariant} (want {MUTANT_INVARIANT[mutation]}): "
              f"{witness.format()}")
        ok = False
    else:
        print(f"R9 mutant refuted: {mutation} -> {witness.format()}")
raise SystemExit(0 if ok else 1)
EOF

python - "$verdict" "$BENCH_DIR" <<'EOF'
import glob, json, os, sys
verdict, bench_dir = sys.argv[1], sys.argv[2]
for path in sorted(glob.glob(os.path.join(bench_dir, "BENCH_*.json"))):
    with open(path) as f:
        rows = json.load(f)
    for r in rows:
        r["ci_gate"] = verdict
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"# stamped ci_gate={verdict} into {path} ({len(rows)} records)")
EOF

echo "== ci_gate: $verdict =="
[ "$verdict" = pass ]
