"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh): the three roofline terms in seconds, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS, and the roofline fraction
(= compute_term / max(term) — the fraction of the step the MXU would be busy
with perfect overlap; 1.0 == compute-bound at peak).
"""
import glob
import json
import os

OUTDIR = os.environ.get("DRYRUN_DIR", "results/dryrun")


def rows(outdir=OUTDIR, pattern="*.json"):
    for f in sorted(glob.glob(os.path.join(outdir, pattern))):
        r = json.load(open(f))
        if r.get("status") != "ok":
            yield {"arch": r.get("arch"), "shape": r.get("shape"),
                   "mesh": "mp" if r.get("multi_pod") else "sp",
                   "status": r.get("status", "?")}
            continue
        rf = r["roofline"]
        mx = max(rf["compute_s"], rf["memory_s"], rf["collective_s"], 1e-30)
        yield {
            "arch": r["arch"], "shape": r["shape"],
            "mesh": "mp" if r["multi_pod"] else "sp", "status": "ok",
            "compute_s": rf["compute_s"], "memory_s": rf["memory_s"],
            "collective_s": rf["collective_s"], "dominant": rf["dominant"],
            "useful_flops_ratio": r["useful_flops_ratio"],
            "roofline_fraction": rf["compute_s"] / mx,
            "hbm_gb_per_dev": r.get("per_device_hbm_bytes", 0) / 1e9,
        }


def main(argv=None):
    del argv                  # uniform LOCAL-bench signature (benchmarks.run)
    print("name,us_per_call,derived")
    for r in rows():
        name = f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}"
        if r["status"] != "ok":
            print(f"{name},,{r['status']}")
            continue
        us = max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6
        print(f"{name},{us:.0f},"
              f"dom={r['dominant']};frac={r['roofline_fraction']:.3f};"
              f"useful={r['useful_flops_ratio']:.2f};"
              f"hbm={r['hbm_gb_per_dev']:.1f}GB")


if __name__ == "__main__":
    main()
