"""Home-aware serving scheduler tests.

Fast tier: the scheduler is a pure-Python decision layer, so routing,
batch formation, spill, aging and eviction are tested without jax; one
small single-device server integration pins the fifo-vs-homed bit-exact
contract on real decode.  Multi-device servers (8-dev flat mesh, the
(2,2,2) emulated-pod mesh) run in subprocesses and are marked slow.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.api import Locale
from repro.runtime.scheduler import Scheduler, kv_bytes_per_token
from repro.runtime.server import DecodeServer, Request

from helpers import tiny

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def req(rid, plen=4, max_new=4, session=None, t=0.0):
    return Request(rid=rid, prompt=np.arange(plen, dtype=np.int32) % 7 + 1,
                   max_new=max_new, session=session, t_arrive=t)


def drive(sch: Scheduler, reqs, pad=8):
    """Run the scheduling loop with the server's cost model, no model."""
    for r in reqs:
        sch.submit(r)
    now, placements_log = 0.0, []
    while sch.has_work():
        now = sch.clock(now)
        wave = sch.form_wave(now)
        if not wave:
            continue
        active = [r for _, r in wave]
        cost = pad + max(r.max_new for r in active)
        for r in active:
            r.out = list(range(r.max_new))
            r.done = True
        sch.complete(wave, now, cost)
        placements_log.append(list(wave))
        now += cost
    return placements_log


def stream(n, sessions=4, seed=0, short=4, long=24, slots=8, pad=8):
    rng = np.random.RandomState(seed)
    w = 1.0 / (1.0 + np.arange(sessions))
    w /= w.sum()
    return [req(i, plen=int(rng.randint(2, pad + 1)),
                max_new=int(long if rng.rand() < 0.3 else short),
                session=f"s{rng.choice(sessions, p=w)}",
                t=float(i // (2 * slots)) * (pad + short))
            for i in range(n)]


# ---------------------------------------------------------------------------
# ownership map
# ---------------------------------------------------------------------------
def test_locale_owners_is_chunk_bounds_ownership():
    # degenerate locale: one device owns every slot
    assert Locale(mesh=None).owners(4) == (0, 0, 0, 0)
    # the scheduler consumes the same map chunk-contiguously
    sch = Scheduler(8, owners=(0, 0, 1, 1, 2, 2, 3, 3))
    assert sch.homes == [0, 1, 2, 3]
    assert sch.slots_of[2] == [4, 5]
    # non-divisible slot counts clip like chunk_bounds (trailing home empty)
    sch = Scheduler(3, owners=(0, 0, 1))
    assert sch.slots_of == {0: [0, 1], 1: [2]}


def test_scheduler_validation():
    with pytest.raises(ValueError, match="unknown policy"):
        Scheduler(4, policy="sjf")
    with pytest.raises(ValueError, match="owners maps"):
        Scheduler(4, owners=(0, 1))


def test_kv_bytes_per_token_is_analytic_cache_row():
    cfg = tiny("qwen3-0.6b")          # pure attention: every layer holds KV
    want = cfg.num_layers * 2 * cfg.num_kv_heads * cfg.head_dim \
        * np.dtype(cfg.dtype).itemsize
    assert kv_bytes_per_token(cfg) == want > 0
    # hybrids price only their attention layers; pure SSM pins no KV at all
    hybrid = tiny("jamba-1.5-large-398b")
    assert 0 < len(hybrid.attn_layers) < hybrid.num_layers
    assert kv_bytes_per_token(hybrid) == len(hybrid.attn_layers) * 2 \
        * hybrid.num_kv_heads * hybrid.head_dim \
        * np.dtype(hybrid.dtype).itemsize
    assert kv_bytes_per_token(tiny("mamba2-2.7b")) == 0


# ---------------------------------------------------------------------------
# policies: formation, routing, invariants
# ---------------------------------------------------------------------------
def test_fifo_is_arrival_order_into_freeing_slots():
    sch = Scheduler(4, owners=(0, 0, 1, 1), policy="fifo")
    rs = [req(i) for i in range(6)]
    log = drive(sch, rs)
    assert [[r.rid for _, r in wave] for wave in log] == [[0, 1, 2, 3], [4, 5]]
    # a fifo request's home is whatever slot freed first, not its session's
    assert [r.home for _, r in log[0]] == [0, 0, 1, 1]


def test_homed_never_decodes_off_assigned_home():
    sch = Scheduler(8, owners=(0, 0, 1, 1, 2, 2, 3, 3), policy="homed",
                    bytes_per_token=4)
    log = drive(sch, stream(40, sessions=5, seed=3))
    placed = 0
    for wave in log:
        for slot, r in wave:
            assert sch.owners[slot] == r.home       # the invariant
            placed += 1
    assert placed == 40 and sch.stats.served == 40


def test_homed_affinity_routes_to_bound_home():
    sch = Scheduler(4, owners=(0, 0, 1, 1), policy="homed", bytes_per_token=2)
    drive(sch, [req(0, session="a")])
    h = sch.binding_home("a")
    assert h is not None
    # quiet queues: the session's next request must go home, and a fresh
    # session must balance onto the other home
    r1, r2 = req(1, session="a"), req(2, session="b")
    for r in (r1, r2):
        sch.submit(r)
    sch.form_wave(100.0)
    assert r1.home == h and r2.home != h
    # and staying home costs nothing
    assert sch.stats.relayout_bytes == 0


def test_homed_spill_is_work_conserving_and_charged():
    # every request is one hot session -> all routed to one home; the other
    # home must pull work over (and pay for the bound cache it drags)
    sch = Scheduler(4, owners=(0, 0, 1, 1), policy="homed", bytes_per_token=8,
                    affinity_slack=100)
    drive(sch, [req(9, session="hot")])          # bind the session first
    rs = [req(i, session="hot", max_new=4, t=50.0) for i in range(4)]
    log = drive(sch, rs)
    assert len(log) == 1, "spill must fill both homes in one wave"
    homes_used = {r.home for _, r in log[0]}
    assert homes_used == {0, 1}
    spilled = sum(hs.spilled_in for hs in sch.stats.homes.values())
    assert spilled >= 1
    assert sch.stats.relayout_events >= 1   # the dragged binding was charged
    for wave in log:                        # invariant survives re-homing
        for slot, r in wave:
            assert sch.owners[slot] == r.home


def test_homed_packing_beats_fifo_on_bimodal_stream():
    """The acceptance shape, deterministically: fewer steps, fewer bytes."""
    results = {}
    for policy in ("fifo", "homed"):
        sch = Scheduler(16, owners=tuple(h for h in range(8) for _ in "xx"),
                        policy=policy, bytes_per_token=128, prompt_pad=8)
        drive(sch, stream(48, sessions=6, seed=0, slots=16))
        results[policy] = sch.stats
    f, h = results["fifo"], results["homed"]
    assert h.steps < f.steps, (h.steps, f.steps)
    assert h.relayout_bytes < f.relayout_bytes
    assert h.wait_pct(50) <= f.wait_pct(50)
    assert f.served == h.served == 48


def test_homed_aging_bounds_starvation():
    # a lone long decode amid a steady diet of shorts is admitted within
    # max_skip skipped waves, not deferred forever
    sch = Scheduler(2, owners=(0, 0), policy="homed", max_skip=2,
                    prompt_pad=4)
    rs = [req(0, max_new=32, session="long")] \
        + [req(i, max_new=2, session=f"s{i}") for i in range(1, 12)]
    log = drive(sch, rs)
    served_at = next(i for i, wave in enumerate(log)
                     if any(r.rid == 0 for _, r in wave))
    assert served_at <= 3, f"long request starved for {served_at} waves"


def test_eviction_is_per_home_lru_and_never_migrates():
    sch = Scheduler(4, owners=(0, 0, 1, 1), policy="homed",
                    bytes_per_token=2, session_capacity=1)
    drive(sch, [req(0, session="a", t=0.0)])
    h_a = sch.binding_home("a")
    # a second session completing on the same home evicts the LRU binding
    r_b = req(1, session="b", t=50.0)
    sch.submit(r_b)
    wave = sch.form_wave(50.0)
    # force b onto a's home for the test regardless of balance
    assert any(r.rid == 1 for _, r in wave)
    sch.complete(wave, 50.0, 8.0)
    if r_b.home == h_a:
        assert sch.binding_home("a") is None       # dropped on its own home…
        evicted = sum(hs.evicted for hs in sch.stats.homes.values())
        assert evicted == 1
    # …and the survivor's binding never moved off the home it was made on
    assert sch.binding_home("b") == r_b.home


# ---------------------------------------------------------------------------
# server integration (single device, fast)
# ---------------------------------------------------------------------------
def test_server_policies_decode_bit_identical_and_report():
    cfg = tiny("qwen3-0.6b", layers=1)
    from repro.models.model import LM
    import jax
    params = LM(cfg).init(jax.random.key(0))
    outs, scheds = {}, {}
    for policy in ("fifo", "homed"):
        srv = DecodeServer(cfg, params, batch_slots=2, max_len=32,
                           scheduler=policy, prompt_pad=6)
        for r in stream(5, sessions=2, seed=1, short=2, long=5,
                        slots=2, pad=6):
            srv.submit(r)
        served = srv.run()
        assert all(r.done for r in served)
        assert all(r.home is not None and r.wait is not None for r in served)
        outs[policy] = {r.rid: r.out for r in served}
        scheds[policy] = srv.scheduler
    assert outs["fifo"] == outs["homed"]        # scheduling never leaks into
    assert scheds["homed"].stats.steps <= scheds["fifo"].stats.steps
    # the launcher's exit report renders without a mesh too
    txt = scheds["homed"].format_summary()
    assert "policy=homed" in txt and "relayout=" in txt


def test_server_rejects_prompt_longer_than_pad():
    cfg = tiny("qwen3-0.6b", layers=1)
    from repro.models.model import LM
    import jax
    params = LM(cfg).init(jax.random.key(0))
    srv = DecodeServer(cfg, params, batch_slots=2, max_len=32, prompt_pad=4)
    with pytest.raises(ValueError, match="exceeds prompt_pad"):
        srv.submit(req(0, plen=6))


# ---------------------------------------------------------------------------
# multi-device servers (subprocess; slow)
# ---------------------------------------------------------------------------
_SERVE_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
from repro.configs import get_config, reduce_config
from repro.configs.base import ShapeSpec
from repro.launch.mesh import make_host_mesh
from repro.models.model import LM
from repro.runtime.server import DecodeServer, Request
from repro.sharding.partition import make_plan

MESH = {mesh!r}
cfg = reduce_config(get_config("qwen3-0.6b"), layers=1)
params = LM(cfg).init(jax.random.key(0))
if MESH == "flat":
    mesh = make_host_mesh(n_data=8, n_model=1)
else:
    mesh = make_host_mesh(n_pods=2, n_data=2, n_model=2)
plan = make_plan(mesh, cfg, ShapeSpec("serve", 32, 16, "decode"))

def make_stream():
    rng = np.random.RandomState(0)
    w = 1.0 / (1.0 + np.arange(4)); w /= w.sum()
    return [Request(rid=i,
                    prompt=rng.randint(0, cfg.vocab_size,
                                       rng.randint(2, 7)).astype(np.int32),
                    max_new=int(12 if rng.rand() < 0.3 else 3),
                    session=f"s{{rng.choice(4, p=w)}}",
                    t_arrive=float(i // 16))
            for i in range(24)]

outs, scheds = {{}}, {{}}
for policy in ("fifo", "homed"):
    srv = DecodeServer(cfg, params, batch_slots=16, max_len=32, plan=plan,
                       scheduler=policy, prompt_pad=6)
    n_homes = len(srv.scheduler.homes)
    for r in make_stream():
        srv.submit(r)
    served = srv.run()
    owners = srv.locale.owners(srv.B)
    for r in served:                      # every request stayed on its home
        assert r.home is not None and r.home in srv.scheduler.homes
    outs[policy] = {{r.rid: tuple(r.out) for r in served}}
    scheds[policy] = srv.scheduler

f, h = scheds["fifo"].stats, scheds["homed"].stats
assert n_homes == (8 if MESH == "flat" else 4), n_homes
assert outs["fifo"] == outs["homed"], "policies diverged"
assert h.relayout_bytes < f.relayout_bytes, (h.relayout_bytes,
                                             f.relayout_bytes)
assert h.steps <= f.steps, (h.steps, f.steps)
if MESH != "flat":
    assert scheds["homed"].homes_per_pod == 2
    assert h.inter_pod_bytes <= f.inter_pod_bytes
print("SERVE_SCHED_OK", MESH, int(f.relayout_bytes), int(h.relayout_bytes))
"""


def _run_sub(code):
    return subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=900,
                          env={**os.environ, "PYTHONPATH": "src"}, cwd=ROOT)


@pytest.mark.slow
def test_serve_homed_vs_fifo_flat_8dev():
    r = _run_sub(_SERVE_CODE.format(mesh="flat"))
    assert "SERVE_SCHED_OK flat" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_serve_homed_vs_fifo_pods_222():
    """The (2,2,2) emulated-pod smoke: 4 homes (pod-major), model axis 2."""
    r = _run_sub(_SERVE_CODE.format(mesh="pods"))
    assert "SERVE_SCHED_OK pods" in r.stdout, r.stdout + r.stderr
