"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # offline CI image: deterministic shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
ATTN_CASES = [
    # (B, H, KV, Sq, Skv, hd, causal, window, bq, bk)
    (1, 2, 2, 128, 128, 32, True, 0, 64, 64),
    (2, 4, 2, 128, 128, 64, True, 0, 64, 64),      # GQA
    (1, 2, 1, 96, 96, 32, True, 0, 64, 64),        # ragged tail + MQA
    (1, 2, 2, 128, 128, 32, True, 48, 64, 64),     # sliding window
    (2, 2, 2, 64, 192, 32, False, 0, 64, 64),      # cross (no mask), Sq != Skv
    (1, 8, 8, 256, 256, 16, True, 0, 128, 128),
]
# fast lane keeps the plain-causal case; the full sweep runs in tier-1
ATTN_PARAMS = [pytest.param(c, marks=() if i < 1 else (pytest.mark.slow,))
               for i, c in enumerate(ATTN_CASES)]


@pytest.mark.parametrize("case", ATTN_PARAMS)
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_flash_attention_vs_ref(case, dtype):
    B, H, KV, Sq, Skv, hd, causal, window, bq, bk = case
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, hd), dtype)
    k = jax.random.normal(ks[1], (B, KV, Skv, hd), dtype)
    v = jax.random.normal(ks[2], (B, KV, Skv, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=bq, block_k=bk)
    expect = ref.attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == "bfloat16" else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.slow
def test_flash_attention_block_skipping_matches_dense_window():
    """SWA with many fully-skipped KV tiles still matches the oracle."""
    q = jax.random.normal(jax.random.key(1), (1, 2, 512, 32))
    k = jax.random.normal(jax.random.key(2), (1, 2, 512, 32))
    v = jax.random.normal(jax.random.key(3), (1, 2, 512, 32))
    out = ops.flash_attention(q, k, v, causal=True, window=64,
                              block_q=64, block_k=64)
    expect = ref.attention_ref(q, k, v, causal=True, window=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# bitonic sort
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("chunks,L", [(1, 64), (4, 128), (8, 256),
                                      pytest.param(2, 1024,
                                                   marks=pytest.mark.slow)])
@pytest.mark.parametrize("dtype", ["int32", "float32"])
def test_bitonic_sort_vs_ref(chunks, L, dtype):
    if dtype == "int32":
        x = jax.random.randint(jax.random.key(0), (chunks, L), -10**6, 10**6,
                               dtype=jnp.int32)
    else:
        x = jax.random.normal(jax.random.key(0), (chunks, L), jnp.float32)
    out = ops.bitonic_sort(x)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref.sort_ref(x)))


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_chunked_sort_property(seed):
    x = jax.random.randint(jax.random.key(seed), (8, 128), -2**30, 2**30,
                           dtype=jnp.int32)
    out = np.asarray(ops.chunked_sort(x))
    np.testing.assert_array_equal(out, np.sort(np.asarray(x).reshape(-1)))


# ---------------------------------------------------------------------------
# localised copy (Fig-1 kernel)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("chunks,L,reps", [(4, 256, 1), (8, 512, 16),
                                           pytest.param(2, 1024, 64,
                                                        marks=pytest.mark.slow)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_localised_copy_vs_ref(chunks, L, reps, dtype):
    x = jax.random.normal(jax.random.key(0), (chunks, L), dtype)
    out = ops.localised_copy(x, reps)
    expect = ref.localised_copy_ref(x, reps)
    tol = 3e-2 if dtype == "bfloat16" else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)
