"""Runtime tests: resume determinism, kill->supervisor relaunch, serving."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.runtime.trainer import Trainer, TrainerConfig
from repro.runtime.server import DecodeServer, Request

from helpers import build, tiny

TRAIN_SCRIPT = """
import sys, json
sys.path.insert(0, "src")
from repro.configs import get_config, reduce_config
from repro.runtime.trainer import Trainer, TrainerConfig
cfg = reduce_config(get_config("qwen3-0.6b"))
import os
t = TrainerConfig(steps=int(sys.argv[2]), global_batch=4, seq_len=32,
                  ckpt_dir=sys.argv[1], ckpt_every=5, log_every=5,
                  schedule_total=int(os.environ.get("REPRO_TOTAL", sys.argv[2])),
                  metrics_path=sys.argv[3] if len(sys.argv) > 3 else None)
res = Trainer(cfg, t).run()
print("FINAL", json.dumps({"step": res["final_step"],
                           "loss": res["final_loss"]}))
"""


def _run_train(tmp, steps, metrics=None, timeout=600, total=None):
    args = [sys.executable, "-c", TRAIN_SCRIPT, str(tmp), str(steps)]
    if metrics:
        args.append(metrics)
    env = dict(os.environ)
    if total:
        env["REPRO_TOTAL"] = str(total)
    return subprocess.run(args, capture_output=True, text=True,
                          timeout=timeout, env=env,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))


@pytest.mark.slow
def test_trainer_runs_and_loss_decreases(tmp_path):
    m = str(tmp_path / "metrics.json")
    r = _run_train(tmp_path / "ck", 30, m)
    assert "FINAL" in r.stdout, r.stdout + r.stderr
    log = json.load(open(m))
    assert log[-1]["loss"] < log[0]["loss"], log


@pytest.mark.slow
def test_resume_is_deterministic(tmp_path):
    """30 straight steps == 15 steps + restart + 15 more (same final loss)."""
    m1 = str(tmp_path / "m1.json")
    r = _run_train(tmp_path / "a", 30, m1)
    assert "FINAL" in r.stdout, r.stdout + r.stderr
    loss_straight = json.load(open(m1))[-1]["loss"]

    r = _run_train(tmp_path / "b", 15, total=30)
    assert "FINAL" in r.stdout, r.stdout + r.stderr
    m2 = str(tmp_path / "m2.json")
    r = _run_train(tmp_path / "b", 30, m2)   # resumes from step 15
    assert "FINAL" in r.stdout, r.stdout + r.stderr
    loss_resumed = json.load(open(m2))[-1]["loss"]
    np.testing.assert_allclose(loss_straight, loss_resumed, rtol=1e-5)


@pytest.mark.slow
def test_supervisor_relaunches_after_crash(tmp_path):
    """First attempt dies mid-run; supervisor relaunches; run completes."""
    from repro.runtime.ft import Supervisor
    crash_script = TRAIN_SCRIPT.replace(
        "res = Trainer(cfg, t).run()",
        "import os\n"
        "marker = sys.argv[1] + '.crashed_once'\n"
        "if not os.path.exists(marker):\n"
        "    open(marker, 'w').write('x')\n"
        "    import threading, time, signal\n"
        "    def killer():\n"
        "        time.sleep(20); os.kill(os.getpid(), signal.SIGKILL)\n"
        "    threading.Thread(target=killer, daemon=True).start()\n"
        "res = Trainer(cfg, t).run()")
    sup = Supervisor(cmd=[sys.executable, "-c", crash_script,
                          str(tmp_path / "ck"), "25"],
                     max_restarts=5, heartbeat_timeout_s=400,
                     env={"PYTHONPATH": "src"})
    cwd = os.getcwd()
    os.chdir(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    try:
        out = sup.run()
    finally:
        os.chdir(cwd)
    assert out["ok"], out
    assert any("FINAL" in l for l in out["stdout"])


def test_supervisor_structured_record_and_hung_restart_budget(tmp_path):
    """`Supervisor.run` always returns a structured failure/success record,
    and a worker that exits 0 only after exhausting `max_restarts` on
    heartbeat kills is a *failure* (it used to be reported as success)."""
    from repro.runtime.ft import Supervisor

    # clean run: completed, no restarts, one history entry
    out = Supervisor(cmd=[sys.executable, "-c", "print('ok')"]).run()
    assert out["ok"] and out["reason"] == "completed"
    assert out["restarts"] == 0 and out["hangs"] == 0
    assert out["final_rc"] == 0 and len(out["history"]) == 1
    assert out["history"][0] == {"rc": 0, "hung": False,
                                 "seconds": out["history"][0]["seconds"],
                                 "lines": 1}

    # crash budget exhausted: max_restarts, final_rc is the crash code
    out = Supervisor(cmd=[sys.executable, "-c", "import sys; sys.exit(3)"],
                     max_restarts=2).run()
    assert not out["ok"] and out["reason"] == "max_restarts"
    assert out["final_rc"] == 3 and out["restarts"] == 3
    assert [h["rc"] for h in out["history"]] == [3, 3, 3]

    # crash once then finish cleanly: still a success (designed recovery)
    flaky = (
        "import os, sys\n"
        "marker = sys.argv[1]\n"
        "if not os.path.exists(marker):\n"
        "    open(marker, 'w').write('x'); sys.exit(9)\n"
        "print('recovered')\n")
    out = Supervisor(cmd=[sys.executable, "-c", flaky,
                          str(tmp_path / "crashed")], max_restarts=2).run()
    assert out["ok"] and out["reason"] == "completed"
    assert out["restarts"] == 1 and out["hangs"] == 0

    # hang (heartbeat kill) twice, then exit 0: the rc==0 exit must NOT be
    # reported as a healthy run once the restart budget went to hangs
    hangy = (
        "import os, sys, time\n"
        "d = sys.argv[1]\n"
        "n = len(os.listdir(d))\n"
        "open(os.path.join(d, str(n)), 'w').write('x')\n"
        "print('beat', flush=True)\n"
        "if n < 2:\n"
        "    os.close(1); os.close(2)\n"  # silent from here on: hung worker
        "    time.sleep(30)\n"            # (stderr shares the pipe)
        "print('DONE')\n")
    d = tmp_path / "attempts"
    d.mkdir()
    out = Supervisor(cmd=[sys.executable, "-c", hangy, str(d)],
                     max_restarts=2, heartbeat_timeout_s=1.0).run()
    assert not out["ok"] and out["reason"] == "hung_restart_budget"
    assert out["hangs"] == 2 and out["final_rc"] == 0
    assert [h["hung"] for h in out["history"]] == [True, True, False]
    assert any("DONE" in l for l in out["stdout"])


@pytest.mark.slow
def test_decode_server_homes_slots_on_multi_device_mesh():
    """Satellite regression: the server's slot-homing locale must carry the
    plan's batch axes as a *tuple* axis (it used to pass the raw list where
    an axis name was expected) and serve identically to the no-mesh server
    on a real >=2-device mesh."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import numpy as np
import jax
from repro.configs import get_config, reduce_config
from repro.configs.base import ShapeSpec
from repro.models.model import LM
from repro.runtime.server import DecodeServer, Request
from repro.sharding.partition import make_plan
from repro.launch.mesh import make_host_mesh

cfg = reduce_config(get_config("qwen3-0.6b"))
model = LM(cfg)
params = model.init(jax.random.key(42))
mesh = make_host_mesh(n_data=2, n_model=1)
plan = make_plan(mesh, cfg, ShapeSpec("d", 64, 4, "decode"))
assert plan.batch_axes == ("data",), plan.batch_axes

def serve(plan_):
    srv = DecodeServer(cfg, params, batch_slots=4, max_len=64, plan=plan_)
    for i in range(4):
        srv.submit(Request(rid=i, prompt=np.asarray([3 + i, 5, 7], np.int32),
                           max_new=4))
    return srv, [r.out for r in srv.run()]

srv, outs = serve(plan)
# the locale carries the batch axes tuple over the real mesh
assert srv.locale.mesh is mesh and srv.locale.axis == ("data",), \\
    (srv.locale.mesh, srv.locale.axis)
assert srv.locale.axis_size == 2
# and slot-homed serving decodes the same tokens as the unplanned server
from repro.sharding.partition import NULL_PLAN
_, outs_ref = serve(NULL_PLAN)
assert outs == outs_ref, (outs, outs_ref)
print("SERVER_SLOTS_OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600,
                       env={**os.environ, "PYTHONPATH": "src"},
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "SERVER_SLOTS_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_decode_server_greedy_matches_manual(tmp_path):
    cfg, model, params = build("qwen3-0.6b")
    srv = DecodeServer(cfg, params, batch_slots=2, max_len=64)
    prompts = [np.array([5, 6, 7], np.int32), np.array([9, 10], np.int32),
               np.array([1, 2, 3], np.int32)]
    for i, p in enumerate(prompts):
        srv.submit(Request(rid=i, prompt=p, max_new=5))
    served = srv.run()
    assert len(served) == 3 and all(r.done for r in served)
    assert all(len(r.out) == 5 for r in served)
    # greedy decode of a single prompt matches a manual prefill+decode loop
    r0 = served[2]  # slot-aligned wave 2: batch of one -> no padding effects
    toks = jnp.asarray(prompts[2][None])
    last, caches = model.prefill(params, {"tokens": toks}, max_len=64)
    cur = int(jnp.argmax(last, -1)[0])
    manual = [cur]
    pos = toks.shape[1]
    for _ in range(4):
        lg, caches = model.decode_step(
            params, caches, {"tokens": jnp.asarray([[cur]], jnp.int32)},
            jnp.int32(pos))
        cur = int(jnp.argmax(lg, -1)[0])
        manual.append(cur)
        pos += 1
    assert r0.out == manual, (r0.out, manual)
