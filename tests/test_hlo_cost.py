"""hlo_cost: the trip-count-aware HLO cost model vs analytic ground truth."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze, parse_module


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_flops_multiplied_by_trip_count():
    W = jnp.zeros((64, 64), jnp.float32)
    x = jnp.zeros((64, 64), jnp.float32)

    def scanned(w, x):
        def body(c, _):
            return w @ c, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    r = analyze(_compile_text(scanned, W, x))
    assert r["flops"] == 10 * 2 * 64 ** 3, r["flops"]
    assert 10 in r["while_trips"]
    # XLA's own cost_analysis undercounts loop bodies (the motivation)
    ca = jax.jit(scanned).lower(W, x).compile().cost_analysis()
    xla = (ca[0] if isinstance(ca, (list, tuple)) else ca)["flops"]
    assert xla < r["flops"]


def test_grad_of_scan_counts_fwd_and_bwd():
    W = jnp.zeros((32, 32), jnp.float32)
    x = jnp.zeros((32, 32), jnp.float32)

    def loss(w, x):
        def body(c, _):
            return w @ c, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return jnp.sum(y)

    r = analyze(_compile_text(jax.grad(loss), W, x))
    # fwd (1 dot) + bwd (2 dots) per iteration
    assert r["flops"] == 7 * 3 * 2 * 32 ** 3, r["flops"]


@pytest.mark.slow
def test_collectives_inside_loops_are_scaled():
    import os
    import subprocess
    import sys
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.hlo_cost import analyze
mesh = jax.make_mesh((4,), ("d",))
x = jnp.zeros((8, 64), jnp.float32)

def f(x):
    def body(c, _):
        s = jax.lax.with_sharding_constraint(c, NamedSharding(mesh, P("d")))
        r = jnp.sum(s, axis=0, keepdims=True)          # cross-shard reduce
        return c + r, None
    y, _ = jax.lax.scan(body, x, None, length=5)
    return y

t = jax.jit(f, in_shardings=NamedSharding(mesh, P("d"))).lower(x).compile().as_text()
r = analyze(t)
counts = r["collective_counts"]
assert any(v >= 5 for v in counts.values()), counts   # scaled by trip count
print("COLL_OK", counts)
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={**os.environ, "PYTHONPATH": "src"},
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), timeout=300)
    assert "COLL_OK" in r.stdout, r.stdout + r.stderr


def test_parse_module_finds_entry_and_computations():
    t = _compile_text(lambda a, b: a @ b + 1.0,
                      jnp.zeros((16, 16)), jnp.zeros((16, 16)))
    comps = parse_module(t)
    assert "__entry__" in comps
    assert analyze(t)["flops"] == 2 * 16 ** 3
