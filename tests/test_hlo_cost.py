"""hlo_cost: the trip-count-aware HLO cost model vs analytic ground truth."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze, parse_module


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_flops_multiplied_by_trip_count():
    W = jnp.zeros((64, 64), jnp.float32)
    x = jnp.zeros((64, 64), jnp.float32)

    def scanned(w, x):
        def body(c, _):
            return w @ c, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    r = analyze(_compile_text(scanned, W, x))
    assert r["flops"] == 10 * 2 * 64 ** 3, r["flops"]
    assert 10 in r["while_trips"]
    # XLA's own cost_analysis undercounts loop bodies (the motivation)
    ca = jax.jit(scanned).lower(W, x).compile().cost_analysis()
    xla = (ca[0] if isinstance(ca, (list, tuple)) else ca)["flops"]
    assert xla < r["flops"]


def test_grad_of_scan_counts_fwd_and_bwd():
    W = jnp.zeros((32, 32), jnp.float32)
    x = jnp.zeros((32, 32), jnp.float32)

    def loss(w, x):
        def body(c, _):
            return w @ c, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return jnp.sum(y)

    r = analyze(_compile_text(jax.grad(loss), W, x))
    # fwd (1 dot) + bwd (2 dots) per iteration
    assert r["flops"] == 7 * 3 * 2 * 32 ** 3, r["flops"]


@pytest.mark.slow
def test_collectives_inside_loops_are_scaled():
    import os
    import subprocess
    import sys
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.hlo_cost import analyze
mesh = jax.make_mesh((4,), ("d",))
x = jnp.zeros((8, 64), jnp.float32)

def f(x):
    def body(c, _):
        s = jax.lax.with_sharding_constraint(c, NamedSharding(mesh, P("d")))
        r = jnp.sum(s, axis=0, keepdims=True)          # cross-shard reduce
        return c + r, None
    y, _ = jax.lax.scan(body, x, None, length=5)
    return y

t = jax.jit(f, in_shardings=NamedSharding(mesh, P("d"))).lower(x).compile().as_text()
r = analyze(t)
counts = r["collective_counts"]
assert any(v >= 5 for v in counts.values()), counts   # scaled by trip count
print("COLL_OK", counts)
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={**os.environ, "PYTHONPATH": "src"},
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), timeout=300)
    assert "COLL_OK" in r.stdout, r.stdout + r.stderr


def test_def_regex_tuple_and_layout_suffixed_shapes():
    """Satellite: _DEF_RE must not skip tuple results whose layouts contain
    parens (TPU tiling like T(8,128)) or dynamic-dim markers."""
    from repro.launch.hlo_cost import _DEF_RE, _PARAM_RE, _parse_shape
    cases = [
        ("  %f = (f32[8,16]{1,0:T(8,128)}, s32[8]{0}) fusion(%a), kind=kLoop",
         "f", "fusion"),
        ("  ROOT %r = f32[8,16]{1,0:T(8,128)} add(%a, %b)", "r", "add"),
        ("  %t = (f32[8,16], s32[8]) custom-call(%a)", "t", "custom-call"),
        ("  %d = s32[<=8]{0} add(%a, %b)", "d", "add"),
    ]
    for line, name, opcode in cases:
        m = _DEF_RE.match(line)
        assert m and m.group(1) == name and m.group(3) == opcode, line
    ps = _PARAM_RE.findall(
        "%p0: f32[8,16]{1,0:T(8,128)}, %p1: (f32[4]{0:T(8)}, s32[4])")
    assert ps == [("p0", "f32[8,16]{1,0:T(8,128)}"),
                  ("p1", "(f32[4]{0:T(8)}, s32[4])")], ps
    # dynamic dims parse at their bound; layout digits are not dims
    assert _parse_shape("s32[<=8]{0}") == [("s32", [8])]
    assert _parse_shape("(f32[2,3]{1,0:T(8,128)}, bf16[4])") == \
        [("f32", [2, 3]), ("bf16", [4])]


def test_collective_group_parsing_all_three_forms():
    from repro.launch.hlo_cost import collective_groups
    brace = collective_groups(
        "%x = f32[8] all-gather(%a), replica_groups={{0,4},{1,5}}")
    assert brace == [[0, 4], [1, 5]], brace
    iota = collective_groups(
        "%x = f32[8] all-reduce(%a), replica_groups=[2,4]<=[4,2]T(1,0)")
    assert iota == [[0, 2, 4, 6], [1, 3, 5, 7]], iota
    flat_iota = collective_groups(
        "%x = f32[8] all-gather(%a), replica_groups=[1,8]<=[8]")
    assert flat_iota == [[0, 1, 2, 3, 4, 5, 6, 7]], flat_iota
    pairs = collective_groups(
        "%x = f32[8] collective-permute(%a), source_target_pairs={{0,2},{2,0}}")
    assert pairs == [[0, 2], [2, 0]], pairs
    assert collective_groups("%x = f32[8] all-reduce(%a), replica_groups={}") \
        == []


def test_analyze_emits_per_op_collective_records():
    """collective_ops carries kind/bytes/wire/groups for every collective."""
    from repro.launch.hlo_cost import analyze
    hlo = """
HloModule m

ENTRY %main (p0: f32[64,64]) -> f32[64,64] {
  %p0 = f32[64,64]{1,0} parameter(0)
  %ag = f32[64,64]{1,0} all-gather(%p0), replica_groups={{0,1},{2,3}}, dimensions={0}
  ROOT %cp = f32[64,64]{1,0} collective-permute(%ag), source_target_pairs={{0,1},{1,0}}
}
"""
    r = analyze(hlo)
    ops = r["collective_ops"]
    assert [o["kind"] for o in ops] == ["all-gather", "collective-permute"]
    ag, cp = ops
    assert ag["group_size"] == 2 and ag["groups"] == [[0, 1], [2, 3]]
    assert ag["bytes"] == 64 * 64 * 4
    assert ag["wire_bytes"] == 64 * 64 * 4 / 2          # (g-1)/g of result
    assert cp["wire_bytes"] == 64 * 64 * 4              # full buffer
    assert all(o["mult"] == 1 for o in ops)


def test_parse_module_finds_entry_and_computations():
    t = _compile_text(lambda a, b: a @ b + 1.0,
                      jnp.zeros((16, 16)), jnp.zeros((16, 16)))
    comps = parse_module(t)
    assert "__entry__" in comps
    assert analyze(t)["flops"] == 2 * 16 ** 3
