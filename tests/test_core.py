"""Core localisation/sort/microbench correctness (single device + property)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # offline CI image: deterministic shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import (Homing, LocalisationPolicy, chunk_bounds,
                        distributed_merge_sort, merge_sorted,
                        repetitive_copy)
from repro.core.microbench import reference as micro_reference
from repro.configs.paper_sort import CASES


def test_chunk_bounds_cover_exactly():
    for n, m in [(100, 8), (64, 8), (1000, 63), (7, 8)]:
        bounds = chunk_bounds(n, m)
        covered = []
        for lo, hi in bounds:
            covered.extend(range(lo, hi))
        assert covered == list(range(n)), (n, m)


@given(st.lists(st.integers(-2**31, 2**31 - 1), min_size=0, max_size=200),
       st.lists(st.integers(-2**31, 2**31 - 1), min_size=0, max_size=200))
@settings(max_examples=30, deadline=None)
def test_merge_sorted_property(a, b):
    a = jnp.sort(jnp.asarray(a, jnp.int32))
    b = jnp.sort(jnp.asarray(b, jnp.int32))
    out = np.asarray(merge_sorted(a, b))
    expect = np.sort(np.concatenate([np.asarray(a), np.asarray(b)]),
                     kind="stable")
    np.testing.assert_array_equal(out, expect)


@pytest.mark.slow
@given(st.integers(0, 2**32 - 1), st.sampled_from([64, 256, 1024]),
       st.sampled_from([2, 4, 8]))
@settings(max_examples=20, deadline=None)
def test_distributed_sort_property(seed, n, m):
    x = jax.random.randint(jax.random.key(seed), (n,), -10**6, 10**6,
                           dtype=jnp.int32)
    out = np.asarray(distributed_merge_sort(x, mesh=None, num_workers=m))
    xs = np.sort(np.asarray(x))
    np.testing.assert_array_equal(out, xs)       # sorted AND a permutation


# fast lane keeps the bench-featured corners (1, 3, 7, 8); tier-1 runs all 8
@pytest.mark.parametrize("case", [
    pytest.param(c, marks=() if c in (1, 3, 7, 8) else (pytest.mark.slow,))
    for c in sorted(CASES)])
def test_all_table1_cases_same_result(case):
    c = CASES[case]
    policy = LocalisationPolicy(localised=c.localised,
                                static_mapping=c.static_mapping,
                                homing=Homing(c.homing))
    x = jax.random.randint(jax.random.key(0), (512,), 0, 10**6, jnp.int32)
    out = np.asarray(distributed_merge_sort(x, mesh=None, policy=policy,
                                            num_workers=8))
    np.testing.assert_array_equal(out, np.sort(np.asarray(x)))


def test_microbench_matches_reference():
    x = jnp.linspace(0.0, 1.0, 256, dtype=jnp.float32)
    for pol in [LocalisationPolicy(localised=True),
                LocalisationPolicy(localised=False,
                                   homing=Homing.HASH_INTERLEAVED)]:
        y = repetitive_copy(x, 7, mesh=None, policy=pol)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(micro_reference(x, 7)),
                                   rtol=1e-6)


@pytest.mark.slow
def test_sort_multidevice_subprocess():
    """8 host devices: all cases produce the sorted array under real sharding."""
    import subprocess, sys, os
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import LocalisationPolicy, Homing, distributed_merge_sort
from repro.core.microbench import repetitive_copy, reference
mesh = jax.make_mesh((8,), ("data",))
x = jax.random.randint(jax.random.key(1), (1 << 14,), 0, 1 << 30, jnp.int32)
expect = np.sort(np.asarray(x))
for loc in [True, False]:
    for st_ in [True, False]:
        for h in [Homing.LOCAL_CHUNKED, Homing.HASH_INTERLEAVED]:
            p = LocalisationPolicy(loc, st_, h)
            y = distributed_merge_sort(x, mesh=mesh, policy=p)
            np.testing.assert_array_equal(np.asarray(y), expect), p
xf = jnp.linspace(0, 1, 1 << 14, dtype=jnp.float32)
for p in [LocalisationPolicy(True), LocalisationPolicy(False, True, Homing.HASH_INTERLEAVED)]:
    np.testing.assert_allclose(np.asarray(repetitive_copy(xf, 5, mesh, p)),
                               np.asarray(reference(xf, 5)), rtol=1e-5)
print("MULTIDEV_OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), timeout=600)
    assert "MULTIDEV_OK" in r.stdout, r.stdout + r.stderr
