"""Shared test helpers: tiny batches for every arch family."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduce_config
from repro.models.model import LM

ALL_ARCHS = ["mixtral-8x7b", "deepseek-moe-16b", "qwen3-0.6b", "glm4-9b",
             "granite-20b", "granite-3-2b", "musicgen-medium", "mamba2-2.7b",
             "jamba-1.5-large-398b", "llama-3.2-vision-90b"]

# the fast lane (-m "not slow") keeps one representative arch;
# the full per-arch sweep still runs in tier-1 (plain `pytest`)
FAST_ARCHS = {"qwen3-0.6b"}
ARCH_PARAMS = [pytest.param(n, marks=() if n in FAST_ARCHS
                            else (pytest.mark.slow,)) for n in ALL_ARCHS]


def tiny(name, **kw):
    return reduce_config(get_config(name), **kw)


def make_batch(cfg, B=2, S=32, key=0, with_targets=True):
    ks = jax.random.split(jax.random.key(key), 4)
    batch = {}
    if cfg.embed_input:
        batch["tokens"] = jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)
    else:
        batch["embeds"] = 0.1 * jax.random.normal(ks[0], (B, S, cfg.d_model))
    if with_targets:
        batch["targets"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
    if cfg.family == "vlm":
        batch["image_embeds"] = 0.1 * jax.random.normal(
            ks[2], (B, cfg.num_image_tokens, cfg.d_model))
    return batch


def build(name, **kw):
    cfg = tiny(name, **kw)
    model = LM(cfg)
    params = model.init(jax.random.key(42))
    return cfg, model, params
