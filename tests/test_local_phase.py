"""VMEM-resident local phase: fused `local_sort` + merge-path `merge_split`.

Property grid pins the two kernels bit-exact against their jnp oracles
(`jnp.sort` rows; `merge_sorted`-then-slice) across duplicates, BIG/inf
sentinel values appearing as *data*, already/reverse-sorted inputs, both
core dtypes and non-power-of-two lengths/leaf counts (the in-VMEM sentinel
padding path).  The engine is then pinned bit-exact under both
``local_phase`` implementations, fast on the 1-device mesh and (slow) on
8-device flat + emulated-pod meshes.  Compiled (interpret=False) variants
are skip-guarded: they only run on a real accelerator.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LOCAL_PHASES, Homing, Locale, LocalisationPolicy,
                        exchange_schedule)
from repro.core.sort import merge_sorted
from repro.kernels import ops

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if ROOT not in sys.path:                 # for the in-process benchmark tests
    sys.path.insert(0, ROOT)
ON_CPU = jax.default_backend() == "cpu"
BIGI = int(jnp.iinfo(jnp.int32).max)


def _rows(name: str, C: int, rows: int = 3):
    """One property-grid corner: (rows, C) arrays worth sorting."""
    key = jax.random.key(C * 31 + rows)
    if name == "dups_int":               # heavy duplicates, int32
        return jax.random.randint(key, (rows, C), -4, 4, dtype=jnp.int32)
    if name == "rand_int":
        return jax.random.randint(key, (rows, C), -10**6, 10**6,
                                  dtype=jnp.int32)
    if name == "sentinel_int":           # BIG sentinel present as real data
        x = jax.random.randint(key, (rows, C), -9, 9, dtype=jnp.int32)
        return x.at[:, ::3].set(BIGI)
    if name == "rand_float":
        return jax.random.normal(key, (rows, C), jnp.float32)
    if name == "sentinel_float":         # +/-inf present as real data
        x = jax.random.normal(key, (rows, C), jnp.float32)
        return x.at[:, ::5].set(jnp.inf).at[:, 1::7].set(-jnp.inf)
    if name == "sorted":
        return jnp.broadcast_to(jnp.arange(C, dtype=jnp.int32), (rows, C))
    if name == "reversed":
        return jnp.broadcast_to(jnp.arange(C, 0, -1, dtype=jnp.int32),
                                (rows, C))
    raise AssertionError(name)


GRID_NAMES = ("dups_int", "rand_int", "sentinel_int", "rand_float",
              "sentinel_float", "sorted", "reversed")
# C=96 -> 3 leaves of 32 (non-power-of-two leaf count), C=1/5/257 ->
# in-VMEM sentinel padding, C=256 -> the clean power-of-two lane
GRID_C = (1, 5, 96, 256, 257)


# ---------------------------------------------------------------------------
# local_sort: fused leaf sorts + merge tree, one VMEM pass
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", GRID_NAMES)
@pytest.mark.parametrize("C", GRID_C)
def test_local_sort_matches_jnp_sort(name, C):
    x = _rows(name, C)
    np.testing.assert_array_equal(np.asarray(ops.local_sort(x)),
                                  np.sort(np.asarray(x), axis=-1))


def test_local_sort_keeps_real_sentinels_with_padding():
    """A BIG-valued *data* element must survive the in-VMEM pad+strip."""
    x = jnp.asarray([[5, BIGI, -3, 1, 2]], jnp.int32)       # C=5 -> pads to 8
    np.testing.assert_array_equal(np.asarray(ops.local_sort(x))[0],
                                  np.asarray([-3, 1, 2, 5, BIGI]))
    xf = jnp.asarray([[jnp.inf, 0.5, -jnp.inf]], jnp.float32)
    np.testing.assert_array_equal(np.asarray(ops.local_sort(xf))[0],
                                  np.asarray([-np.inf, 0.5, np.inf],
                                             np.float32))


# ---------------------------------------------------------------------------
# merge_split: only the kept half, bit-exact vs merge_sorted + slice
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", GRID_NAMES)
@pytest.mark.parametrize("C", GRID_C)
def test_merge_split_matches_merge_sorted_slice(name, C):
    rows = 4
    a = jnp.sort(_rows(name, C, rows), axis=-1)
    b = jnp.sort(_rows(name, C, rows)[::-1], axis=-1)
    keep = (jnp.arange(rows) % 2) == 0               # mixed per-row flags
    out = np.asarray(ops.merge_split(a, b, keep))
    for r in range(rows):
        full = np.asarray(merge_sorted(a[r], b[r]))
        expect = full[:C] if bool(keep[r]) else full[C:]
        np.testing.assert_array_equal(out[r], expect,
                                      err_msg=f"{name} C={C} row={r}")


def test_merge_split_scalar_flag_and_tie_stability():
    """Scalar keep flag broadcasts; duplicate ties split exactly as the
    stable rank merge does (a-elements before equal b-elements)."""
    a = jnp.asarray([[1, 2, 2, 7]], jnp.int32)
    b = jnp.asarray([[2, 2, 3, 7]], jnp.int32)
    full = np.asarray(merge_sorted(a[0], b[0]))
    for keep in (True, False):
        got = np.asarray(ops.merge_split(a, b, jnp.asarray(keep)))[0]
        np.testing.assert_array_equal(got, full[:4] if keep else full[4:])


@pytest.mark.skipif(ON_CPU, reason="interpret=False needs a real accelerator "
                                   "(TPU); CPU only runs interpret mode")
def test_kernels_compiled_mode_matches_interpret():
    x = _rows("rand_int", 256)
    np.testing.assert_array_equal(
        np.asarray(ops.local_sort(x, interpret=False)),
        np.asarray(ops.local_sort(x, interpret=True)))
    a = jnp.sort(_rows("dups_int", 128), axis=-1)
    b = jnp.sort(_rows("rand_int", 128), axis=-1)
    keep = jnp.asarray([True, False, True])
    np.testing.assert_array_equal(
        np.asarray(ops.merge_split(a, b, keep, interpret=False)),
        np.asarray(ops.merge_split(a, b, keep, interpret=True)))


# ---------------------------------------------------------------------------
# the engine under both local_phase implementations
# ---------------------------------------------------------------------------
ENGINE_POLICIES = [LocalisationPolicy(True, True, Homing.LOCAL_CHUNKED),
                   LocalisationPolicy(True, True, Homing.HASH_INTERLEAVED),
                   LocalisationPolicy(False, True, Homing.HASH_INTERLEAVED)]


@pytest.mark.parametrize("local_phase", LOCAL_PHASES)
@pytest.mark.parametrize("policy", ENGINE_POLICIES,
                         ids=lambda p: p.name)
def test_engine_single_device_bit_exact_per_phase(policy, local_phase):
    """1-device mesh, n=1000 => padded chunk, non-power-of-two leaves."""
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    fn = Locale(mesh=mesh, policy=policy).workload(
        "engine", num_workers=8, local_phase=local_phase)
    for n, dt in ((1000, jnp.int32), (513, jnp.float32)):
        x = (jax.random.randint(jax.random.key(n), (n,), -10**5, 10**5,
                                dtype=dt) if dt == jnp.int32
             else jax.random.normal(jax.random.key(n), (n,), dt))
        expect = np.sort(np.asarray(x))
        np.testing.assert_array_equal(np.asarray(fn(x)), expect,
                                      err_msg=f"{policy.name} {local_phase}")


def test_local_phase_validation():
    with pytest.raises(ValueError, match="local_phase"):
        Locale().workload("engine", local_phase="nope")
    # a callable leaf sort cannot run inside the fused kernel
    with pytest.raises(ValueError, match="callable"):
        Locale().workload("engine", local_sort=jnp.sort,
                          local_phase="pallas")
    # the constraint tree has no kernel local phase
    with pytest.raises(ValueError, match="shard_map"):
        Locale().workload("sort", backend="constraint", local_phase="pallas")
    # "reference" is the constraint tree's nature: accepted as a no-op
    fn = Locale().workload("sort", backend="constraint",
                           local_phase="reference", num_workers=4)
    x = jnp.asarray([3, 1, 2], jnp.int32)
    np.testing.assert_array_equal(np.asarray(fn(x)), [1, 2, 3])


@pytest.mark.slow
def test_engine_8dev_and_pods_bit_exact_both_phases():
    """Acceptance: flat 8-device and (2,4,1) emulated-pod meshes, all
    localised policies (incl. hierarchical — the batched merge_split
    replay), pallas vs reference, bit-identical to jnp.sort."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import Homing, Locale, LocalisationPolicy
from repro.launch.mesh import make_host_mesh
flat = Locale.auto()
pods = Locale(mesh=make_host_mesh(n_pods=2, n_data=4, n_model=1),
              axis=("pod", "data"))
grids = [(flat, [LocalisationPolicy(True, True, Homing.LOCAL_CHUNKED),
                 LocalisationPolicy(True, True, Homing.HASH_INTERLEAVED),
                 LocalisationPolicy(False, True, Homing.HASH_INTERLEAVED)]),
         (pods, [LocalisationPolicy.hierarchical(),
                 LocalisationPolicy.hierarchical(inner="hash"),
                 LocalisationPolicy(True, True, Homing.LOCAL_CHUNKED)])]
for locale, pols in grids:
    for pol in pols:
        for phase in ("pallas", "reference"):
            for n, dt in [(1 << 13, jnp.int32), (5000, jnp.float32)]:
                if dt == jnp.int32:
                    x = jax.random.randint(jax.random.key(1), (n,), -10**6,
                                           10**6, dtype=dt)
                else:
                    x = jax.random.normal(jax.random.key(1), (n,), dt)
                expect = np.asarray(jnp.sort(x))
                fn = locale.with_policy(pol).workload(
                    "sort", backend="shard_map", local_phase=phase)
                np.testing.assert_array_equal(np.asarray(fn(x)), expect,
                    err_msg=f"{pol.name} {phase} {n}")
print("PHASES_OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={**os.environ, "PYTHONPATH": "src"},
                       cwd=ROOT, timeout=900)
    assert "PHASES_OK" in r.stdout, r.stdout + r.stderr


# ---------------------------------------------------------------------------
# exchange_schedule: the local half of the byte model
# ---------------------------------------------------------------------------
def test_schedule_prices_pallas_local_phase_strictly_cheaper():
    n = 1 << 13
    for sizes in [(8,), (2, 4), (4, 2)]:
        pols = [LocalisationPolicy(),
                LocalisationPolicy(True, True, Homing.HASH_INTERLEAVED)]
        if len(sizes) > 1:
            pols.append(LocalisationPolicy.hierarchical())
        for pol in pols:
            pal = exchange_schedule(n, sizes, pol, local_phase="pallas")
            ref = exchange_schedule(n, sizes, pol, local_phase="reference")
            tot = lambda s, k: sum(r[k] for r in s)
            # the collective half of the schedule is phase-independent
            coll = lambda s: [(r["level"], r["op"], r["inter_pod_bytes"],
                               r["intra_pod_bytes"]) for r in s
                              if r["op"] in ("ppermute", "all_gather",
                                             "all_to_all")]
            assert coll(pal) == coll(ref)
            # the local half is strictly cheaper fused: one VMEM round trip
            # for the whole tree, and only the kept half of every split
            assert tot(pal, "local_hbm_bytes") < tot(ref, "local_hbm_bytes")
            assert tot(pal, "local_merge_elems") < \
                tot(ref, "local_merge_elems"), (sizes, pol.name)
            # every merge_split computes exactly half the reference elems
            for rp, rr in zip(pal, ref):
                assert rp["op"] == rr["op"] and rp["level"] == rr["level"]
                if rp["op"] == "merge_split":
                    assert 2 * rp["local_merge_elems"] == \
                        rr["local_merge_elems"]
                # local ops move no collective bytes, and vice versa
                assert (rp["local_hbm_bytes"] == 0) or \
                    (rp["inter_pod_bytes"] == 0 and
                     rp["intra_pod_bytes"] == 0)


def test_schedule_nonlocalised_local_cost_phase_independent():
    """No fused path without ownership: gathers interleave every level."""
    pol = LocalisationPolicy(False, True, Homing.LOCAL_CHUNKED)
    pal = exchange_schedule(1 << 12, (8,), pol, local_phase="pallas")
    ref = exchange_schedule(1 << 12, (8,), pol, local_phase="reference")
    assert pal == ref
    ops_seen = [r["op"] for r in pal]
    assert ops_seen[:2] == ["all_gather", "local_sort"]
    assert ops_seen.count("merge") == 3              # log2(8) tree levels


# ---------------------------------------------------------------------------
# satellites: benchmark capture + regression gate
# ---------------------------------------------------------------------------
def test_bench_kernels_capture_reaches_json_records():
    """run.py's LOCAL capture: kernel rows must reach parse_records (they
    used to be printed uncaptured, so BENCH_kernels.json could never fill)."""
    from benchmarks.run import JSON_FILES, parse_records, run_local
    out = run_local("bench_kernels",
                    ["--only", "local,merge", "--chunks", "1", "--logcs", "6"])
    recs = parse_records(out)
    names = {r["name"] for r in recs}
    assert any(n.startswith("kernel_local_fused_") for n in names), out
    assert any(n.startswith("kernel_merge_split_") for n in names), out
    prefixes = JSON_FILES["BENCH_kernels.json"]
    assert all(any(r["name"].startswith(p) for p in prefixes) for r in recs)


def test_compare_flags_synthetic_regression(tmp_path):
    import json
    base = [{"name": "sort_x", "us": 100.0}, {"name": "sort_y", "us": 80.0},
            {"name": "structure_only", "us": None}]
    new = [{"name": "sort_x", "us": 150.0}, {"name": "sort_y", "us": 70.0},
           {"name": "structure_only", "us": None}]
    bp, np_ = tmp_path / "base.json", tmp_path / "new.json"
    bp.write_text(json.dumps(base))
    np_.write_text(json.dumps(new))
    from benchmarks.compare import main as compare_main
    # 50% regression on sort_x: above a 10% gate -> fail, above 60% -> pass
    assert compare_main([str(bp), str(np_), "--fail-above", "10"]) == 1
    assert compare_main([str(bp), str(np_), "--fail-above", "60"]) == 0
    assert compare_main([str(bp), str(np_)]) == 0    # no gate, report only
