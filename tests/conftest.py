import os

# smoke tests and benches must see exactly 1 device (the dry-run, and only
# the dry-run, creates the 512-device placeholder platform in a subprocess).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
