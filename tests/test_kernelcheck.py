"""kernelcheck + netverify: R5-R8 provably fire on the committed fixtures,
run clean on every real kernel, and the exchange-network descriptor agrees
with the permutations the runtime actually issues.

The descriptor-vs-runtime agreement tests trace `shard_map_sort` on real
emulated meshes, so they run in one subprocess per device count (the
XLA_FLAGS must be set before jax initialises); everything else —
fixtures, 0-1 certification, the sentinel lint, the merge_split keep
validation — is single-device and runs in-process.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core  # noqa: F401  (must precede repro.kernels imports)
from repro.analysis import certify_supported_meshes, zero_one_certify
from repro.analysis.findings import Report, Severity, normalize_rules
from repro.analysis.fixtures import (dead_lane_kernel, gapped_index_map,
                                     inverted_keep_network,
                                     nonbijective_network,
                                     oob_index_map, overlapping_index_map)
from repro.analysis.kernelcheck import (r5_block_coverage, r7_index_arith,
                                        r8_dead_lanes)
from repro.analysis.netverify import _substage_findings
from repro.analysis.vmem import pallas_call_facts
from repro.kernels.local_sort import local_sort
from repro.kernels.merge_split import merge_split

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _check(jaxpr_like) -> Report:
    rep = Report(target="t")
    facts = pallas_call_facts(jaxpr_like)
    assert facts, "fixture produced no pallas_call"
    r5_block_coverage(rep, facts)
    r7_index_arith(rep, facts)
    r8_dead_lanes(rep, facts)
    return rep


# ---------------------------------------------------------------------------
# R5/R8 fixtures: each committed known-bad pattern is provably flagged
# ---------------------------------------------------------------------------
def test_r5_overlapping_index_map_is_error():
    rep = _check(overlapping_index_map())
    errs = [f for f in rep.errors if f.rule == "R5"]
    assert errs and "write race" in errs[0].message, rep.format()


def test_r5_gap_is_warn_not_error():
    rep = _check(gapped_index_map())
    assert not rep.errors, rep.format()
    warns = [f for f in rep.findings
             if f.rule == "R5" and f.severity == Severity.WARN]
    assert warns and "coverage gap" in warns[0].message, rep.format()


def test_r5_oob_input_read_is_error():
    rep = _check(oob_index_map())
    errs = [f for f in rep.errors if f.rule == "R5"]
    assert errs and "out-of-bounds read" in errs[0].message, rep.format()


def test_r8_dead_lane_is_flagged():
    rep = _check(dead_lane_kernel())
    dead = [f for f in rep.findings if f.rule == "R8"]
    assert dead and "dead lane" in dead[0].message, rep.format()


# ---------------------------------------------------------------------------
# real kernels are clean (incl. flash-attention's revisit dims + pl.when)
# ---------------------------------------------------------------------------
def test_real_kernels_clean_under_r5_r7_r8():
    jx = jax.make_jaxpr(local_sort)(
        jax.ShapeDtypeStruct((4, 1024), jnp.float32))
    assert _check(jx).clean

    from repro.kernels.flash_attention import flash_attention
    q = jax.ShapeDtypeStruct((2, 2, 256, 64), jnp.float32)
    jx = jax.make_jaxpr(lambda q, k, v: flash_attention(q, k, v))(q, q, q)
    rep = _check(jx)
    assert rep.clean, rep.format()   # out revisited per KV step: no race

    a = jax.ShapeDtypeStruct((2, 512), jnp.float32)
    keep = jnp.array([True, False])
    jx = jax.make_jaxpr(lambda a, b: merge_split(a, b, keep))(a, a)
    assert _check(jx).clean


# ---------------------------------------------------------------------------
# R7: sentinel lint (representability + tie-stability)
# ---------------------------------------------------------------------------
def test_r7_sentinel_fixtures():
    from repro.analysis.kernelcheck import _check_sentinel
    rep = Report(target="r7")
    _check_sentinel(rep, "f", np.dtype(np.int32), 1 << 31)   # overflows
    assert rep.errors and "not representable" in rep.errors[0].message
    rep2 = Report(target="r7")
    _check_sentinel(rep2, "f", np.dtype(np.float16), 65504.0)  # finite max
    assert not rep2.errors and any("tie" in f.message for f in rep2.findings)
    rep3 = Report(target="r7")
    _check_sentinel(rep3, "f", np.dtype(np.float32), np.inf)
    _check_sentinel(rep3, "f", np.dtype(np.int32),
                    np.iinfo(np.int32).max)
    assert rep3.clean, rep3.format()


def test_r7_rank_overflow_via_sentinel_override():
    # a block too wide for int32 merge-path ranks must be an ERROR;
    # exercised through the facts of a real (tiny) kernel with the
    # index dtype shrunk so the bound trips without a 2-GiB trace.
    jx = jax.make_jaxpr(local_sort)(
        jax.ShapeDtypeStruct((1, 1 << 10), jnp.float32))
    rep = Report(target="r7")
    r7_index_arith(rep, pallas_call_facts(jx), index_dtype="int8")
    assert any(f.rule == "R7" and "overflow" in f.message
               for f in rep.errors), rep.format()


# ---------------------------------------------------------------------------
# R6: structural + 0-1 certification over the descriptor fixtures
# ---------------------------------------------------------------------------
def test_r6_nonbijective_perm_fixture_is_structural_error():
    findings = _substage_findings(nonbijective_network())
    assert findings and all(f.severity == Severity.ERROR for f in findings)
    assert any("bijection" in f.message for f in findings)


def test_r6_inverted_keep_fixture_fails_zero_one_only():
    net = inverted_keep_network()
    assert not _substage_findings(net)        # structurally sound...
    witness = zero_one_certify(net)
    assert witness is not None                # ...but does not sort
    assert len(witness) == net.m


def test_r6_certificate_covers_every_supported_mesh():
    cert = certify_supported_meshes(max_devices=16)
    assert set(cert) == {"loc-static-local", "loc-static-hash",
                         "hier.hash-loc-static-local",
                         "hier.hash-loc-static-hash"}
    for rec in cert.values():
        assert rec["failed"] == [], rec
    # flat policies certify every shape; hierarchical the multi-axis ones
    assert (2, 4) in cert["hier.hash-loc-static-local"]["certified"]
    assert (16,) in cert["loc-static-local"]["certified"]
    assert all(len(s) >= 2
               for s in cert["hier.hash-loc-static-hash"]["certified"])


def test_normalize_rules():
    assert normalize_rules(None) == tuple(f"R{i}" for i in range(1, 12))
    assert normalize_rules("all") == normalize_rules(["all"])
    assert normalize_rules(["R5", "r6"]) == ("R5", "R6")
    assert normalize_rules("R5,R8") == ("R5", "R8")
    assert normalize_rules("r9,r10,r11") == ("R9", "R10", "R11")
    with pytest.raises(ValueError, match="unknown rule"):
        normalize_rules(["R12"])


# ---------------------------------------------------------------------------
# merge_split keep validation (the silent-broadcast fix)
# ---------------------------------------------------------------------------
def test_merge_split_rejects_wrong_length_keep():
    a = jnp.tile(jnp.arange(8, dtype=jnp.float32), (3, 1))
    with pytest.raises(ValueError, match="length-3 vector"):
        merge_split(a, a, jnp.array([True, False]))
    with pytest.raises(ValueError, match="scalar or a length-3"):
        merge_split(a, a, jnp.ones((3, 1), bool))
    # scalar and exact-length flags still work, bit-exact vs reference
    lo = merge_split(a, a + 0.5, True)
    ref = np.sort(np.concatenate([a[0], np.asarray(a[0] + 0.5)]))[:8]
    np.testing.assert_array_equal(np.asarray(lo[0]), ref)
    flags = jnp.array([True, False, True])
    out = merge_split(a, a + 0.5, flags)
    np.testing.assert_array_equal(np.asarray(out[1]),
                                  np.sort(np.concatenate(
                                      [a[1], np.asarray(a[1] + 0.5)]))[8:])


# ---------------------------------------------------------------------------
# descriptor-vs-runtime agreement: the ppermutes the engine actually
# issues are exactly the descriptor's, flat + hierarchical, both local
# phases — one subprocess per device count
# ---------------------------------------------------------------------------
AGREEMENT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={m}"
import jax, jax.numpy as jnp
from repro.core.engine import (NetExchange, engine_granule,
                               exchange_network, shard_map_sort)
from repro.core.homing import Homing
from repro.core.localisation import LocalisationPolicy
from repro.launch.mesh import make_host_mesh

def issued_ppermutes(jaxpr_like):
    out, seen = [], set()
    def subs(v):
        if hasattr(v, "eqns"):
            yield v
        elif hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):
            yield v.jaxpr
        elif isinstance(v, (list, tuple)):
            for x in v:
                yield from subs(x)
    def visit(j):
        if id(j) in seen:
            return
        seen.add(id(j))
        for e in j.eqns:
            if e.primitive.name == "ppermute":
                ax = e.params["axis_name"]
                ax = ax[0] if isinstance(ax, tuple) and len(ax) == 1 else ax
                out.append((ax, tuple(sorted(map(tuple, e.params["perm"])))))
            for v in e.params.values():
                for s in subs(v):
                    visit(s)
    for s in subs(jaxpr_like):
        visit(s)
    return out

m = {m}
cases = [("flat", LocalisationPolicy(), None, "data"),
         ("hash", LocalisationPolicy(homing=Homing.HASH_INTERLEAVED),
          None, "data")]
if m >= 4:
    cases += [("hier", LocalisationPolicy.hierarchical(), 2,
               ("pod", "data")),
              ("hier-hash", LocalisationPolicy.hierarchical(inner="hash"),
               2, ("pod", "data"))]

for name, policy, pods, axis in cases:
    if pods:
        mesh = make_host_mesh(n_pods=pods, n_data=m // pods, n_model=1)
        sizes = (pods, m // pods)
    else:
        mesh = make_host_mesh(n_data=m, n_model=1)
        sizes = (m,)
    net = exchange_network(policy, sizes,
                           axis if isinstance(axis, tuple) else (axis,))
    want = [(lv.axis, tuple(sorted(lv.perm))) for lv in net.levels
            if isinstance(lv, NetExchange)]
    g = engine_granule(m, None, policy.homing == Homing.HASH_INTERLEAVED)
    n = ((1 << 10) + g - 1) // g * g
    x = jax.ShapeDtypeStruct((n,), jnp.int32)
    for lp in ("pallas", "reference"):
        jx = jax.make_jaxpr(lambda v: shard_map_sort(
            v, mesh=mesh, policy=policy, axis=axis, local_phase=lp))(x)
        got = issued_ppermutes(jx)
        assert got == want, (name, lp, got, want)
    print(f"AGREE {{name}} levels={{len(want)}}")
print("ALL_AGREE")
"""


@pytest.mark.parametrize("m", [2, 4, 8])
def test_descriptor_matches_runtime_ppermutes(m):
    r = subprocess.run(
        [sys.executable, "-c", AGREEMENT.format(m=m)],
        capture_output=True, text=True, cwd=ROOT, timeout=420,
        env={**os.environ, "PYTHONPATH": "src"})
    assert r.returncode == 0, r.stdout + r.stderr
    assert "ALL_AGREE" in r.stdout
    if m >= 4:
        assert "AGREE hier" in r.stdout    # hierarchical cases ran too
