"""Observability layer tests: tracelog primitives, metrics registry, and
trace reconciliation against real (seeded, bursty) serving runs.

Fast tier: tracer/metrics unit tests, pure-scheduler reconciliation, the
committed corrupt-trace fixture, the supervisor's structured failure
event, and a 1-device traced serve whose identities `reconcile` proves.
The 8-device traced serve (flat and pod meshes) runs in a subprocess and
is marked slow, like every other multi-device test.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.obs import NULL_TRACER, Tracer, get_tracer, set_tracer
from repro.obs import metrics as obs_metrics
from repro.obs.reconcile import ReconcileError, reconcile
from repro.obs.tracelog import SCHEMA, read_jsonl, to_chrome
from repro.runtime.scheduler import Scheduler
from repro.runtime.server import DecodeServer, Request

from helpers import tiny

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CORRUPT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "data", "corrupt_trace.jsonl")


# ---------------------------------------------------------------------------
# tracelog primitives
# ---------------------------------------------------------------------------
def test_tracer_spans_nest_and_counters_accumulate(tmp_path):
    path = str(tmp_path / "t.jsonl")
    tr = Tracer(path, run="unit")
    with tr.span("outer", cat="t", a=1) as sp:
        tr.count("bytes", 10, cat="t")
        tr.count("bytes", 5, cat="t")
        with tr.span("inner", cat="t"):
            tr.gauge("depth", 3, cat="t")
        sp.set(b=2)
        sp.event("mark", x=1)
    tr.close()
    recs = tr.records()
    byname = {r["name"]: r for r in recs}
    assert recs[0]["name"] == "trace.meta"
    assert recs[0]["args"]["schema"] == SCHEMA
    assert recs[0]["args"]["run"] == "unit"
    # spans emit at exit: inner closes before outer, with parent links
    assert byname["inner"]["parent"] == "outer"
    assert byname["outer"]["parent"] is None
    assert byname["outer"]["args"] == {"a": 1, "b": 2}
    assert byname["outer"]["dur"] >= byname["inner"]["dur"] >= 0
    assert byname["mark"]["args"]["parent"] == "outer"
    # counters carry increment and running total
    counters = [r for r in recs if r["name"] == "bytes"]
    assert [c["value"] for c in counters] == [10, 5]
    assert [c["total"] for c in counters] == [10, 15]
    assert tr.total("bytes") == 15
    # the streaming sink wrote the same records the memory list holds
    assert read_jsonl(path) == recs


def test_null_tracer_is_free_and_global_default():
    assert not NULL_TRACER.enabled
    with NULL_TRACER.span("x", cat="t", a=1) as sp:
        sp.set(b=2).event("y")
        NULL_TRACER.count("c", 5)
        NULL_TRACER.gauge("g", 1)
    assert NULL_TRACER.records() == []
    assert get_tracer() is NULL_TRACER       # process default is off
    tr = Tracer()
    assert set_tracer(tr) is NULL_TRACER
    assert get_tracer() is tr
    assert set_tracer(None) is tr            # None resets
    assert get_tracer() is NULL_TRACER


def test_chrome_export_shape():
    tr = Tracer()
    with tr.span("s", cat="c"):
        tr.event("e", cat="c")
    tr.count("n", 2)
    tr.gauge("g", 7)
    ev = to_chrome(tr.records())["traceEvents"]
    phases = {e["name"]: e["ph"] for e in ev}
    assert phases["s"] == "X" and phases["e"] == "i"
    assert phases["n"] == "C" and phases["g"] == "C"
    assert all({"name", "ph", "ts", "pid", "tid"} <= set(e) for e in ev)
    json.dumps(ev)                           # everything serialises


# ---------------------------------------------------------------------------
# pure-scheduler reconciliation (no jax): seeded bursty stream
# ---------------------------------------------------------------------------
def bursty(n, sessions=4, seed=0, slots=8, pad=8):
    rng = np.random.RandomState(seed)
    w = 1.0 / (1.0 + np.arange(sessions))
    w /= w.sum()
    return [Request(rid=i,
                    prompt=(rng.randint(1, 7, rng.randint(2, pad + 1))
                            .astype(np.int32)),
                    max_new=int(12 if rng.rand() < 0.3 else 3),
                    session=f"s{rng.choice(sessions, p=w)}",
                    t_arrive=float(i // (2 * slots)) * 12.0)
            for i in range(n)]


def drive(sch, reqs, pad=8):
    for r in reqs:
        sch.submit(r)
    now = 0.0
    while sch.has_work():
        now = sch.clock(now)
        wave = sch.form_wave(now)
        if not wave:
            continue
        active = [r for _, r in wave]
        cost = pad + max(r.max_new for r in active)
        for r in active:
            r.out = list(range(r.max_new))
            r.done = True
        sch.complete(wave, now, cost)
        now += cost


@pytest.mark.parametrize("policy", ["fifo", "homed"])
def test_reconcile_pure_scheduler(policy):
    tr = Tracer(policy=policy)
    sch = Scheduler(8, owners=(0, 0, 1, 1, 2, 2, 3, 3), policy=policy,
                    bytes_per_token=4, page_size=2, page_capacity=8,
                    prompt_pad=8, tracer=tr)
    drive(sch, bursty(40, seed=3))
    summary = sch.emit_summary()
    assert summary["served"] == 40 and summary["waves"] > 1
    report = reconcile(tr.records())
    assert report["segments"] == 1 and report["served"] == 40
    if policy == "homed":
        assert summary["relayout_bytes"] > 0      # identities were non-vacuous
    assert summary["pages_attached"] >= 0


def test_reconcile_survives_forced_invalidation():
    """pool events carry actual refs deltas, so the acquire-release-
    invalidate ledger balances even after a mid-flight evacuation."""
    from repro.runtime.ft import evacuate_home
    tr = Tracer()
    sch = Scheduler(4, owners=(0, 0, 1, 1), policy="homed",
                    bytes_per_token=4, page_size=2, page_capacity=8,
                    prompt_pad=8, tracer=tr)
    reqs = bursty(20, seed=5, slots=4)
    for r in reqs[:10]:
        sch.submit(r)
    now = sch.clock(0.0)
    wave = sch.form_wave(now)
    for _, r in wave:
        r.out = [1]
        r.done = True
    # evacuate home 0 while its first wave is still in flight
    rec = evacuate_home(sch, home=0)
    sch.complete(wave, now + 4.0, 4.0)
    for r in reqs[10:]:
        sch.submit(r)
    drive(sch, [])
    sch.emit_summary()
    assert any(r["name"] == "ft.evacuate" for r in tr.records())
    assert rec["pages_dropped"] >= 0
    reconcile(tr.records())                       # identities still hold


def test_reconcile_rejects_broken_identities():
    tr = Tracer()
    sch = Scheduler(4, owners=(0, 0, 1, 1), policy="homed",
                    bytes_per_token=4, prompt_pad=8, tracer=tr)
    drive(sch, bursty(16, seed=7, slots=4))
    sch.emit_summary()
    good = tr.records()
    reconcile(good)

    def corrupt(mutate):
        recs = [json.loads(json.dumps(r)) for r in good]
        mutate(recs)
        with pytest.raises(ReconcileError):
            reconcile(recs)

    # drop one charge event -> an off-home decode goes unpaid
    corrupt(lambda rs: rs.remove(
        next(r for r in rs if r["name"] == "sched.charge")))
    # inflate the summary's byte counter -> I-bytes
    def inflate(rs):
        s = next(r for r in rs if r["name"] == "sched.summary")
        s["args"]["relayout_bytes"] += 64
    corrupt(inflate)
    # drop a placement -> served / waves identities break
    corrupt(lambda rs: rs.remove(
        next(r for r in rs if r["name"] == "sched.place")))
    # malformed record kind -> schema rejection
    def badkind(rs):
        rs[1]["kind"] = "mystery"
    corrupt(badkind)
    # scheduler events with no closing summary -> dangling segment
    corrupt(lambda rs: rs.remove(
        next(r for r in rs if r["name"] == "sched.summary")))


def test_committed_corrupt_fixture_is_rejected():
    """The committed fixture is a real trace whose summary claims fewer
    relayout bytes than its own charge events add up to — the validator
    must prove it wrong, and the CLI must exit nonzero."""
    records = read_jsonl(CORRUPT)
    with pytest.raises(ReconcileError, match="I-bytes"):
        reconcile(records)
    from repro.launch.tracelog import main as tracelog_main
    assert tracelog_main([CORRUPT, "--validate"]) == 1
    assert tracelog_main([CORRUPT]) == 0          # summary mode still reads


# ---------------------------------------------------------------------------
# engine budget stamping
# ---------------------------------------------------------------------------
def test_engine_sort_stamps_analytic_schedule():
    from repro.core.engine import make_engine_fn
    from repro.core.localisation import LocalisationPolicy
    tr = Tracer()
    set_tracer(tr)
    try:
        fn = make_engine_fn(None, LocalisationPolicy())
        x = np.random.RandomState(0).randint(0, 997, 128).astype(np.int32)
        y = np.asarray(fn(x))
    finally:
        set_tracer(None)
    assert (y == np.sort(x)).all()
    spans = [r for r in tr.records() if r["name"] == "engine.sort"]
    assert len(spans) == 1
    levels = [r for r in tr.records()
              if r["name"] == "engine.exchange_level"]
    assert levels and all(lv["args"]["call"] == spans[0]["args"]["call"]
                          for lv in levels)
    reconcile_engine_only(tr.records())
    # corrupt one stamped level -> I-engine catches the lie
    bad = [json.loads(json.dumps(r)) for r in tr.records()]
    next(r for r in bad if r["name"] == "engine.exchange_level"
         )["args"]["local_hbm_bytes"] += 1
    with pytest.raises(ReconcileError, match="I-engine"):
        reconcile_engine_only(bad)


def reconcile_engine_only(records):
    from repro.obs.reconcile import check_engine, check_schema
    check_schema(records)
    check_engine(records)


# ---------------------------------------------------------------------------
# supervisor fleet events
# ---------------------------------------------------------------------------
def test_supervisor_hung_restart_budget_emits_failure_event(tmp_path):
    from repro.runtime.ft import Supervisor
    hangy = (
        "import os, sys, time\n"
        "d = sys.argv[1]\n"
        "n = len(os.listdir(d))\n"
        "open(os.path.join(d, str(n)), 'w').write('x')\n"
        "print('beat', flush=True)\n"
        "if n < 2:\n"
        "    os.close(1); os.close(2)\n"
        "    time.sleep(30)\n"
        "print('DONE')\n")
    d = tmp_path / "attempts"
    d.mkdir()
    tr = Tracer()
    out = Supervisor(cmd=[sys.executable, "-c", hangy, str(d)],
                     max_restarts=2, heartbeat_timeout_s=1.0,
                     tracer=tr).run()
    assert not out["ok"] and out["reason"] == "hung_restart_budget"
    attempts = [r for r in tr.records() if r["name"] == "ft.attempt"]
    assert [a["args"]["hung"] for a in attempts] == [True, True, False]
    results = [r for r in tr.records() if r["name"] == "ft.result"]
    assert len(results) == 1
    assert results[0]["args"]["ok"] is False
    assert results[0]["args"]["reason"] == "hung_restart_budget"
    assert results[0]["args"]["hangs"] == 2


# ---------------------------------------------------------------------------
# traced serve, 1 device (fast) and 8 devices (slow subprocess)
# ---------------------------------------------------------------------------
def test_traced_serve_single_device_reconciles(tmp_path):
    cfg = tiny("qwen3-0.6b", layers=1)
    from repro.models.model import LM
    import jax
    params = LM(cfg).init(jax.random.key(0))
    path = str(tmp_path / "serve.jsonl")
    tr = Tracer(path, policy="homed")
    srv = DecodeServer(cfg, params, batch_slots=2, max_len=32,
                       scheduler="homed", prompt_pad=6, tracer=tr)
    for r in bursty(6, sessions=2, seed=1, slots=2, pad=6):
        r.max_new = min(r.max_new, 5)
        srv.submit(r)
    served = srv.run()
    assert len(served) == 6 and all(r.done for r in served)
    summary = srv.scheduler.emit_summary()
    tr.close()
    records = read_jsonl(path)
    report = reconcile(records)
    assert report["segments"] == 1 and report["served"] == 6
    # the serve-layer spans landed in the same stream
    names = {r["name"] for r in records}
    assert {"serve.refill", "serve.decode", "sched.form_wave",
            "sched.route", "sched.place"} <= names
    # summary event == canonical dict == bench rows (one rendering path)
    ev = next(r for r in records if r["name"] == "sched.summary")
    assert ev["args"]["served"] == summary["served"] == 6
    rows = obs_metrics.bench_rows("t", summary, 1e6)
    assert rows[0].startswith("t,") and "_wait,," in rows[1]


_TRACED_SERVE_8DEV = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
from repro.configs import get_config, reduce_config
from repro.configs.base import ShapeSpec
from repro.launch.mesh import make_host_mesh
from repro.models.model import LM
from repro.obs import Tracer
from repro.obs.reconcile import reconcile
from repro.runtime.server import DecodeServer, Request
from repro.sharding.partition import make_plan

MESH = {mesh!r}
cfg = reduce_config(get_config("qwen3-0.6b"), layers=1)
params = LM(cfg).init(jax.random.key(0))
if MESH == "flat":
    mesh = make_host_mesh(n_data=8, n_model=1)
else:
    mesh = make_host_mesh(n_pods=2, n_data=2, n_model=2)
plan = make_plan(mesh, cfg, ShapeSpec("serve", 32, 16, "decode"))

rng = np.random.RandomState(0)
w = 1.0 / (1.0 + np.arange(4)); w /= w.sum()
tr = Tracer(mesh=MESH, policy="homed")
srv = DecodeServer(cfg, params, batch_slots=16, max_len=32, plan=plan,
                   scheduler="homed", prompt_pad=6, tracer=tr)
for i in range(24):
    srv.submit(Request(
        rid=i,
        prompt=rng.randint(0, cfg.vocab_size,
                           rng.randint(2, 7)).astype(np.int32),
        max_new=int(12 if rng.rand() < 0.3 else 3),
        session=f"s{{rng.choice(4, p=w)}}",
        t_arrive=float(i // 16)))
served = srv.run()
assert len(served) == 24
summary = srv.scheduler.emit_summary()
report = reconcile(tr.records())
assert report["segments"] == 1 and report["served"] == 24
assert summary["relayout_bytes"] > 0       # cross-home charges reconciled
if MESH != "flat":
    assert summary["inter_pod_bytes"] >= 0
print("TRACED_SERVE_OK", MESH, report["served"],
      summary["relayout_bytes"])
"""


@pytest.mark.slow
@pytest.mark.parametrize("mesh", ["flat", "pods"])
def test_traced_serve_8dev_reconciles(mesh):
    r = subprocess.run(
        [sys.executable, "-c", _TRACED_SERVE_8DEV.format(mesh=mesh)],
        capture_output=True, text=True, timeout=900,
        env={**os.environ, "PYTHONPATH": "src"}, cwd=ROOT)
    assert "TRACED_SERVE_OK" in r.stdout, r.stdout + r.stderr


# ---------------------------------------------------------------------------
# compare.py wave-wait latency gate
# ---------------------------------------------------------------------------
def test_compare_gates_wait_latency(tmp_path):
    sys.path.insert(0, ROOT)
    try:
        from benchmarks.compare import wait_regressions
    finally:
        sys.path.pop(0)
    base = {"serve_homed_flat8_wait": {"p50": 4.0, "p99": 10.0},
            "serve_x": {"tok_s": 100.0}}
    # within threshold: fine
    assert wait_regressions(
        base, {"serve_homed_flat8_wait": {"p50": 4.4, "p99": 10.0}},
        fail_above=25.0) == []
    # p99 blowup: gated
    bad = wait_regressions(
        base, {"serve_homed_flat8_wait": {"p50": 4.0, "p99": 20.0}},
        fail_above=25.0)
    assert len(bad) == 1 and "p99" in bad[0]
    # zero-base waits appearing is a regression too
    bad = wait_regressions(
        {"w_wait": {"p50": 0.0, "p99": 0.0}},
        {"w_wait": {"p50": 2.0, "p99": 5.0}}, fail_above=25.0)
    assert len(bad) == 2
    # no threshold -> no gate
    assert wait_regressions(base, base, fail_above=None) == []
