"""Paged KV pool tests: pure accounting properties plus the scheduler /
fleet-reliability interplay.

The pool is pure Python over immutable tuples, so the property tests run
randomized sequences of acquire/release/invalidate against brute-force
oracles — no jax, no devices.  The end-to-end bit-identity of *attached*
pages vs computed ones is pinned by the server tests in
tests/test_scheduler.py; here we prove the accounting layer those tests
ride on.
"""
import numpy as np
import pytest

from repro.runtime import ft, kvpool
from repro.runtime.kvpool import PageStore
from repro.runtime.scheduler import Scheduler
from repro.runtime.server import Request


def blocks_of(prompt, ps):
    return kvpool.prompt_blocks(prompt, ps)


# ---------------------------------------------------------------------------
# block-chain construction
# ---------------------------------------------------------------------------
def test_prompt_blocks_cover_only_full_pages_before_the_last_token():
    # 9 tokens, page 4: pages [0:4) and [4:8) are closed; the page holding
    # token 8 (the last prompt token) is still being written -> excluded
    p = list(range(1, 10))
    b = blocks_of(p, 4)
    assert b == (tuple(p[:4]), tuple(p[:8]))
    # exact multiple: the final page holds the last token -> excluded too
    assert blocks_of(p[:8], 4) == (tuple(p[:4]),)
    assert blocks_of(p[:4], 4) == ()
    assert blocks_of([], 4) == () and blocks_of(p, 0) == ()


def test_block_keys_are_radix_prefixes():
    # equal leading tokens => equal leading keys; divergence at token i
    # changes every key from the page containing i onward (COW property)
    a = blocks_of([1, 2, 3, 4, 5, 6, 7, 8, 9], 2)
    b = blocks_of([1, 2, 3, 4, 9, 6, 7, 8, 9], 2)
    assert a[:2] == b[:2]            # pages before the divergence shared
    assert all(x != y for x, y in zip(a[2:], b[2:]))  # never alias after


# ---------------------------------------------------------------------------
# longest-prefix lookup vs a brute-force oracle
# ---------------------------------------------------------------------------
def test_lookup_matches_bruteforce_oracle():
    rng = np.random.RandomState(0)
    for _ in range(200):
        plen = rng.randint(1, 17)
        prompt = rng.randint(1, 4, size=plen).tolist()
        chain = blocks_of(prompt, 2)
        # a pool holding random keys drawn from several prompts' chains
        pool_keys = set()
        for _ in range(rng.randint(0, 4)):
            other = rng.randint(1, 4, size=rng.randint(1, 17)).tolist()
            ob = blocks_of(other, 2)
            pool_keys |= set(ob[:rng.randint(0, len(ob) + 1)])
        pages = tuple(kvpool.Page(k, 0, 0.0) for k in sorted(pool_keys))
        want = 0
        while want < len(chain) and chain[want] in pool_keys:
            want += 1
        assert kvpool.lookup(pages, chain) == want


# ---------------------------------------------------------------------------
# refcount balance under random acquire/release/invalidate
# ---------------------------------------------------------------------------
def test_refcount_balance_property():
    rng = np.random.RandomState(1)
    for trial in range(50):
        capacity = int(rng.randint(1, 9))
        pages = ()
        inflight = []                     # chains acquired, not yet released
        now = 0.0
        for step in range(60):
            now += 1.0
            op = rng.rand()
            if op < 0.5 or not inflight:
                prompt = rng.randint(1, 4, size=rng.randint(1, 13)).tolist()
                chain = blocks_of(prompt, 2)
                pages, hit = kvpool.acquire(pages, chain, capacity, now)
                assert 0 <= hit <= len(chain)
                inflight.append(chain)
            elif op < 0.9:
                chain = inflight.pop(rng.randint(len(inflight)))
                pages = kvpool.release(pages, chain, now)
            else:
                pages = kvpool.invalidate(pages)   # device-loss: refs wiped
                # in-flight requests keep private copies; their later
                # release must be tolerated (checked when they pop above)
            # invariants at every step
            assert len(pages) <= capacity
            keys = [p.key for p in pages]
            assert len(keys) == len(set(keys)), "duplicate pooled key"
            assert all(p.refs >= 0 for p in pages)
            # every ref is owned by an in-flight chain
            owned = {}
            for chain in inflight:
                for k in chain:
                    owned[k] = owned.get(k, 0) + 1
            for p in pages:
                assert p.refs <= owned.get(p.key, 0), (
                    f"trial {trial} step {step}: page {p.key} holds "
                    f"{p.refs} refs, only {owned.get(p.key, 0)} in flight")
        # quiescence: releasing everything leaves zero refs everywhere
        for chain in inflight:
            now += 1.0
            pages = kvpool.release(pages, chain, now)
        assert all(p.refs == 0 for p in pages)


def test_acquire_evicts_lru_unreferenced_and_pins_full():
    ps = 2
    a, b, c = blocks_of([1, 1, 9], ps), blocks_of([2, 2, 9], ps), \
        blocks_of([3, 3, 9], ps)
    pages, _ = kvpool.acquire((), a, 2, now=1.0)
    pages, _ = kvpool.acquire(pages, b, 2, now=2.0)
    # both pinned: c cannot insert (pool pinned full) — not a crash
    pages, hit = kvpool.acquire(pages, c, 2, now=3.0)
    assert hit == 0 and {p.key for p in pages} == {a[0], b[0]}
    pages = kvpool.release(pages, c, now=3.5)     # absent key tolerated
    # free a: now the LRU unreferenced page (a) is evicted for c
    pages = kvpool.release(pages, a, now=4.0)
    pages = kvpool.release(pages, b, now=5.0)
    pages, _ = kvpool.acquire(pages, c, 2, now=6.0)
    assert {p.key for p in pages} == {b[0], c[0]}


# ---------------------------------------------------------------------------
# PageStore pruning follows the accounting layer
# ---------------------------------------------------------------------------
def test_pagestore_prunes_to_live_keys():
    st = PageStore()
    st.put(0, "k1", "c1"), st.put(0, "k2", "c2"), st.put(1, "k1", "x")
    assert st.has(0, "k1") and st.get(0, "k2") == "c2"
    assert st.prune(0, ["k2"]) == 1           # k1 dead on home 0
    assert not st.has(0, "k1") and st.has(0, "k2")
    assert st.has(1, "k1"), "homes are independent"
    st.clear()
    assert not st.has(1, "k1")


# ---------------------------------------------------------------------------
# scheduler interplay: mid-flight invalidation is the ft path, not a crash
# ---------------------------------------------------------------------------
def _sched(**kw):
    base = dict(n_slots=2, owners=(0, 1), policy="homed", prompt_pad=8,
                page_size=2, page_capacity=8)
    base.update(kw)
    return Scheduler(**base)


def _req(rid, session, plen=7):
    return Request(rid=rid, prompt=np.arange(plen, dtype=np.int32) % 5 + 1,
                   max_new=2, session=session, t_arrive=0.0)


def test_evacuation_mid_flight_then_fresh_charged_prefill():
    sch = _sched(bytes_per_token=2)
    store = PageStore()
    r1 = _req(0, "sA")
    sch.submit(r1)
    wave = sch.form_wave(0.0)
    assert len(wave) == 1
    home = wave[0][1].home
    assert len(sch.pool_keys(home)) == 3      # (7-1)//2 pages pinned
    store.put(home, sch.pool_keys(home)[0], "content")

    # the home dies mid-flight: pages dropped regardless of refcounts
    rec = ft.evacuate_home(sch, home, store=store)
    assert rec["pages_dropped"] == 3 and rec["content_pruned"] == 1
    assert sch.pool_keys(home) == [] and not store.has(
        home, sch.pool_keys(home)[0] if sch.pool_keys(home) else "k")

    # completing the in-flight request releases nothing — and must not
    # crash or drive a refcount negative
    r1.out = [1, 2]
    sch.complete(wave, now=1.0)
    assert sch.pool_keys(home) == []

    # the session returns: no pooled prefix -> zero pages attached, a
    # fresh prefill (and its affinity/relayout accounting is unchanged)
    r2 = _req(1, "sA")
    sch.submit(r2)
    wave2 = sch.form_wave(2.0)
    assert len(wave2) == 1
    assert wave2[0][1]._attached == 0
    assert sch.stats.pages_attached == 0
    r2.out = [1, 2]
    sch.complete(wave2, now=3.0)
    # quiescent: the re-pinned chain is back to refs 0, pool consistent
    assert all(p.refs == 0 for h in sch.homes for p in sch.state.pool(h))


def test_returning_session_attaches_without_evacuation():
    # control for the test above: same flow, no evacuation -> full hit
    sch = _sched()
    r1 = _req(0, "sA")
    sch.submit(r1)
    wave = sch.form_wave(0.0)
    r1.out = [1, 2]
    sch.complete(wave, now=1.0)
    r2 = _req(1, "sA")
    sch.submit(r2)
    wave2 = sch.form_wave(2.0)
    assert wave2[0][1]._attached == 3
    assert sch.stats.prefix_hits_full == 1
    assert sch.prefill_rows_saved() == pytest.approx(3 * 2 / 8)


def test_evacuate_all_homes():
    sch = _sched()
    for i, s in enumerate(["sA", "sB"]):
        sch.submit(_req(i, s))
    wave = sch.form_wave(0.0)
    assert len(wave) == 2
    total = sum(len(sch.pool_keys(h)) for h in sch.homes)
    assert total == 6
    rec = ft.evacuate_home(sch)                  # home=None: every home
    assert rec["pages_dropped"] == 6
    for _, r in wave:
        r.out = [1]
    sch.complete(wave, now=1.0)                  # tolerated, no refs left
    assert all(not sch.pool_keys(h) for h in sch.homes)
