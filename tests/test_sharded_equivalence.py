"""Sharded == single-device numerics: the strongest sharding-spec test.

Runs a tiny model's train step on a real (2 data x 2 model) host mesh with
the full production plan (TP + SP + constraints + KV-expand path) and
asserts the loss matches the unsharded run.
"""
import os
import subprocess
import sys

import pytest

CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from functools import partial
from repro.configs import get_config, reduce_config
from repro.configs.base import ShapeSpec
from repro.sharding.partition import (make_plan, param_specs, batch_specs,
                                      full_opt_specs, NULL_PLAN)
from repro.models.model import LM
from repro.models.steps import make_train_step, init_opt_state, make_loss_fn
from repro.optim import AdamW

for name in ["qwen3-0.6b", "mixtral-8x7b", "mamba2-2.7b", "jamba-1.5-large-398b"]:
    base = reduce_config(get_config(name))
    # heads=4/kv=2 on a 2-way model axis exercises TP + the GQA paths
    cfg = base.replace(parallel=base.parallel.__class__(
        fsdp=True, sequence_shard=True, remat=True, microbatches=2))
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    shape = ShapeSpec("t", 32, 4, "train")
    plan = make_plan(mesh, cfg, shape)
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    opt = AdamW(lr=1e-3)
    batch = {"targets": jax.random.randint(jax.random.key(2), (4, 32), 0,
                                           cfg.vocab_size)}
    if cfg.embed_input:
        batch["tokens"] = jax.random.randint(jax.random.key(1), (4, 32), 0,
                                             cfg.vocab_size)
    else:
        batch["embeds"] = 0.1*jax.random.normal(jax.random.key(1), (4, 32, cfg.d_model))
    if cfg.family == "vlm":
        batch["image_embeds"] = 0.1*jax.random.normal(
            jax.random.key(3), (4, cfg.num_image_tokens, cfg.d_model))

    # single-device reference
    loss_ref = make_loss_fn(model, cfg, NULL_PLAN)(params, batch)[1]

    nm = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda s: isinstance(s, P))
    ostate = init_opt_state(cfg, opt, params)
    step = jax.jit(make_train_step(model, cfg, plan, opt),
                   in_shardings=(nm(param_specs(params, plan, cfg)),
                                 nm(full_opt_specs(ostate, params, plan, cfg)),
                                 nm(batch_specs(batch, plan))))
    _, _, m = step(params, ostate, batch)
    np.testing.assert_allclose(float(m["loss"]), float(loss_ref),
                               rtol=2e-4, atol=2e-4)
    print(f"EQ_OK {name} sharded={float(m['loss']):.5f} ref={float(loss_ref):.5f}")
print("ALL_EQ_OK")
"""


@pytest.mark.slow
def test_sharded_train_matches_single_device():
    r = subprocess.run([sys.executable, "-c", CODE], capture_output=True,
                       text=True, env={**os.environ, "PYTHONPATH": "src"},
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), timeout=900)
    assert "ALL_EQ_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]
