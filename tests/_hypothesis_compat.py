"""Deterministic fallback for `hypothesis` in offline images.

The CI image cannot pip-install anything, so the property tests degrade to a
fixed, seeded example sweep when the real package is missing.  Import as:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_compat import given, settings, strategies as st

Only the surface these tests use is provided: `given`, `settings`
(max_examples / deadline), and `strategies.integers / lists / sampled_from`.
Each strategy draws from a `random.Random` seeded per test function, with the
first two examples pinned to the strategy's boundary values so edge cases
(empty lists, INT_MIN/INT_MAX) are always exercised.
"""
from __future__ import annotations

import random
import zlib


class _Strategy:
    def example(self, rng: random.Random, index: int):
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, min_value=None, max_value=None):
        self.lo = -(2 ** 63) if min_value is None else min_value
        self.hi = 2 ** 63 - 1 if max_value is None else max_value

    def example(self, rng, index):
        if index == 0:
            return self.lo
        if index == 1:
            return self.hi
        return rng.randint(self.lo, self.hi)


class _Lists(_Strategy):
    def __init__(self, elements: _Strategy, min_size=0, max_size=None):
        self.elements = elements
        self.min_size = min_size
        self.max_size = max_size if max_size is not None else min_size + 32

    def example(self, rng, index):
        if index == 0:
            size = self.min_size
        elif index == 1:
            size = self.max_size
        else:
            # coarse size grid: random *values* but few distinct shapes, so
            # jax tests don't recompile on every example
            size = rng.choice((self.min_size,
                               (self.min_size + self.max_size) // 2,
                               self.max_size))
        return [self.elements.example(rng, 2) for _ in range(size)]


class _SampledFrom(_Strategy):
    def __init__(self, options):
        self.options = list(options)

    def example(self, rng, index):
        if index < len(self.options):
            return self.options[index]
        return rng.choice(self.options)


class strategies:
    """Namespace mirroring `hypothesis.strategies` (the used subset)."""

    @staticmethod
    def integers(min_value=None, max_value=None):
        return _Integers(min_value, max_value)

    @staticmethod
    def lists(elements, *, min_size=0, max_size=None):
        return _Lists(elements, min_size, max_size)

    @staticmethod
    def sampled_from(options):
        return _SampledFrom(options)


def settings(max_examples: int = 10, deadline=None, **_ignored):
    def deco(fn):
        fn._compat_settings = {"max_examples": max_examples}
        return fn
    return deco


def given(*strats: _Strategy):
    def deco(fn):
        # NOTE: no functools.wraps — pytest must see a zero-arg signature,
        # otherwise the strategy parameters look like missing fixtures.
        def wrapper():
            n = getattr(fn, "_compat_settings", {}).get("max_examples", 10)
            # seeded per test name: stable across runs and machines
            rng = random.Random(zlib.adler32(fn.__name__.encode()))
            for i in range(n):
                fn(*(s.example(rng, i) for s in strats))
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.hypothesis_compat_shim = True
        return wrapper
    return deco
