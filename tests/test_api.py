"""The unified `Locale`/`Homed` placement API: contracts + property tests.

Fast tier runs the single-device mesh and the degenerate (mesh=None) locale;
the slow tier runs the acceptance sweep on an 8-device host mesh:
`Locale.workload("sort")` bit-exact vs `jnp.sort` for every policy x backend.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # offline CI image: deterministic shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import (Homed, Homing, Locale, LocalisationPolicy,
                        check_divisible, chunk_bounds)
from repro.core.api import register_workload

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _mesh1():
    return jax.make_mesh((len(jax.devices()),), ("data",))


# ---------------------------------------------------------------------------
# Locale.put -> Homed round-trips
# ---------------------------------------------------------------------------
@given(st.lists(st.integers(-2**31, 2**31 - 1), min_size=1, max_size=96))
@settings(max_examples=20, deadline=None)
def test_put_roundtrip_preserves_logical_order(vals):
    x = jnp.asarray(vals, jnp.int32)
    for homing in (Homing.LOCAL_CHUNKED, Homing.HASH_INTERLEAVED):
        for mesh in (None, _mesh1()):
            loc = Locale(mesh=mesh, policy=LocalisationPolicy(homing=homing))
            h = loc.put(x)
            assert isinstance(h, Homed) and h.homing == homing
            np.testing.assert_array_equal(np.asarray(h.logical()),
                                          np.asarray(x))


def test_put_pad_strips_like_sort_padding():
    for loc in (Locale(mesh=_mesh1()), Locale(mesh=None)):
        x = jnp.arange(13, dtype=jnp.int32)
        h = loc.put(x, pad=True)
        # pad granule is the axis size (1 here), so content survives intact
        np.testing.assert_array_equal(np.asarray(h.logical())[:13],
                                      np.arange(13))


def test_check_divisible_names_homing_and_sizes():
    with pytest.raises(ValueError, match=r"7 % 8.*pad_to_multiple"):
        check_divisible(7, 8, Homing.HASH_INTERLEAVED, "data")
    with pytest.raises(ValueError, match="local"):
        check_divisible(5, 4, Homing.LOCAL_CHUNKED, "data")


# ---------------------------------------------------------------------------
# Locale.pin: strict no-op without a mesh / under runtime mapping
# ---------------------------------------------------------------------------
@given(st.lists(st.integers(-1000, 1000), min_size=4, max_size=64))
@settings(max_examples=10, deadline=None)
def test_pin_noop_without_mesh_or_static(vals):
    x = jnp.asarray(vals, jnp.float32)
    assert Locale(mesh=None).pin(x) is x
    auto = LocalisationPolicy(static_mapping=False)
    assert Locale(mesh=_mesh1(), policy=auto).pin(x) is x


def test_pin_tree_noop_without_mesh():
    tree = {"k": jnp.zeros((2, 4, 8)), "pos": jnp.zeros((2,))}
    out = Locale(mesh=None).pin_tree(tree, dim=1)
    assert out["k"] is tree["k"] and out["pos"] is tree["pos"]


def test_pin_rejects_mixed_homing():
    loc = Locale(mesh=_mesh1(),
                 policy=LocalisationPolicy(homing=Homing.LOCAL_CHUNKED))
    h = Homed(jnp.arange(8.0), Homing.HASH_INTERLEAVED)
    with pytest.raises(TypeError, match="hash.*local"):
        loc.pin(h)
    # ...but the auto corner stays a strict no-op, mismatch or not
    auto = loc.with_policy(LocalisationPolicy(static_mapping=False,
                                              homing=Homing.LOCAL_CHUNKED))
    assert auto.pin(h) is h


def test_pin_homed_preserves_placed_form():
    """pin(put(x)) must stay shape-compatible with put(x) (same homing)."""
    loc = Locale(mesh=_mesh1(),
                 policy=LocalisationPolicy(homing=Homing.HASH_INTERLEAVED))
    h = loc.put(jnp.arange(12, dtype=jnp.int32))
    h2 = loc.pin(h)
    assert h2.data.shape == h.data.shape
    out = jax.tree.map(lambda a, b: a + b, h, h2)     # no shape mismatch
    np.testing.assert_array_equal(np.asarray(out.logical()),
                                  2 * np.arange(12))


# ---------------------------------------------------------------------------
# Homed: layout metadata travels with the array
# ---------------------------------------------------------------------------
def test_mixed_homing_is_a_tree_structure_error():
    a = Homed(jnp.ones(4), Homing.LOCAL_CHUNKED)
    b = Homed(jnp.ones(4), Homing.HASH_INTERLEAVED)
    with pytest.raises(ValueError):
        jax.tree.map(lambda u, v: u + v, a, b)


def test_homed_passes_through_jit():
    h = Homed(jnp.arange(8.0), Homing.HASH_INTERLEAVED)
    out = jax.jit(lambda v: jax.tree.map(lambda d: d * 2, v))(h)
    assert isinstance(out, Homed) and out.homing == Homing.HASH_INTERLEAVED
    np.testing.assert_allclose(np.asarray(out.logical()),
                               2 * np.arange(8.0))


# ---------------------------------------------------------------------------
# chunk_bounds: ownership math, including m > n (empty tail chunks)
# ---------------------------------------------------------------------------
@given(st.integers(0, 500), st.integers(1, 64))
@settings(max_examples=30, deadline=None)
def test_chunk_bounds_cover_exactly_even_when_m_exceeds_n(n, m):
    bounds = chunk_bounds(n, m)
    assert len(bounds) == m
    covered = [i for lo, hi in bounds for i in range(lo, hi)]
    assert covered == list(range(n)), (n, m)
    if m > n:   # the tail workers own empty chunks, not out-of-range ones
        assert all(lo == hi == n for lo, hi in bounds[n:])


# ---------------------------------------------------------------------------
# workload registry
# ---------------------------------------------------------------------------
def test_unknown_workload_and_backend_rejected():
    with pytest.raises(ValueError, match="unknown workload"):
        Locale().workload("nope")
    with pytest.raises(ValueError, match="unknown backend"):
        Locale().workload("sort", backend="nope")


def test_register_workload_extends_registry():
    @register_workload("_test_double")
    def _double(locale, *, factor=2):
        return locale.jit(lambda x: x * factor, donate=())

    fn = Locale().workload("_test_double", factor=3)
    np.testing.assert_array_equal(np.asarray(fn(jnp.arange(4))),
                                  3 * np.arange(4))


@pytest.mark.parametrize("backend", ["constraint", "shard_map"])
def test_workload_sort_bit_exact_single_device(backend):
    """All 8 policy corners x both backends vs jnp.sort (1-device mesh)."""
    locale = Locale(mesh=_mesh1())
    x0 = jax.random.randint(jax.random.key(0), (513,), -10**6, 10**6,
                            dtype=jnp.int32)
    expect = np.sort(np.asarray(x0))
    for loc in (True, False):
        for static in (True, False):
            for h in (Homing.LOCAL_CHUNKED, Homing.HASH_INTERLEAVED):
                pol = LocalisationPolicy(loc, static, h)
                fn = locale.with_policy(pol).workload(
                    "sort", backend=backend, num_workers=8,
                    local_sort=jnp.sort)
                np.testing.assert_array_equal(np.asarray(fn(jnp.array(x0))),
                                              expect, err_msg=pol.name)


def test_microbench_auto_policy_emits_no_constraints():
    """Satellite regression: the 'leave it to the compiler' baseline must
    not sneak a chunk-contiguous constraint in via localise()."""
    auto = LocalisationPolicy(localised=False, static_mapping=False,
                              homing=Homing.HASH_INTERLEAVED)
    fn = Locale(mesh=_mesh1(), policy=auto).workload("microbench", reps=3)
    txt = fn.lower(jnp.linspace(0, 1, 64)).as_text()
    assert "Sharding" not in txt, "auto baseline leaked a layout constraint"
    # and the static non-localised case still pins layouts
    static = LocalisationPolicy(localised=False, static_mapping=True,
                                homing=Homing.HASH_INTERLEAVED)
    fn = Locale(mesh=_mesh1(), policy=static).workload("microbench", reps=3)
    assert "Sharding" in fn.lower(jnp.linspace(0, 1, 64)).as_text()


# ---------------------------------------------------------------------------
# multi-axis locales: Locale(mesh, axis=("pod", "data")) end-to-end
# ---------------------------------------------------------------------------
def _pod_mesh1():
    """A (1,1,1)-shape (pod, data, model) mesh: the multi-axis *type* paths
    on the single test-process device; real pod shapes run in the slow
    subprocess tests."""
    return jax.make_mesh((1, 1, 1), ("pod", "data", "model"))


def test_multi_axis_locale_placement_roundtrips():
    for homing in (Homing.LOCAL_CHUNKED, Homing.HASH_INTERLEAVED):
        loc = Locale(mesh=_pod_mesh1(), axis=("pod", "data"),
                     policy=LocalisationPolicy(homing=homing))
        assert loc.axis_size == 1
        x = jnp.arange(24, dtype=jnp.int32)
        h = loc.put(x)
        assert h.homing == homing and h.axis == ("pod", "data")
        np.testing.assert_array_equal(np.asarray(h.logical()), np.arange(24))
        # pin accepts both raw arrays and Homed under the tuple axis
        pinned = jax.jit(lambda v: loc.pin(v))(x)
        np.testing.assert_array_equal(np.asarray(pinned), np.arange(24))
        np.testing.assert_array_equal(
            np.asarray(jax.jit(lambda v: loc.localise(v))(x)), np.arange(24))


def test_multi_axis_locale_make_and_workloads():
    loc = Locale(mesh=_pod_mesh1(), axis=("pod", "data"))
    born = loc.make((8, 2), lambda idx: np.ones((8, 2), np.float32)[idx])
    assert born.shape == (8, 2)
    x = jax.random.randint(jax.random.key(0), (513,), -10**6, 10**6,
                           dtype=jnp.int32)
    expect = np.sort(np.asarray(x))
    for backend in ("constraint", "shard_map"):
        fn = loc.workload("sort", backend=backend, num_workers=8,
                          local_sort=jnp.sort)
        np.testing.assert_array_equal(np.asarray(fn(jnp.array(x))), expect,
                                      err_msg=backend)
    mb = loc.workload("microbench", reps=2)
    out = mb(jnp.linspace(0, 1, 16))
    assert out.shape == (16,)


# ---------------------------------------------------------------------------
# deprecation shims: removed after two PRs of warnings
# ---------------------------------------------------------------------------
def test_free_function_shims_are_gone():
    """The pre-Locale free functions were deprecation shims for two PRs and
    are now removed; the building blocks live only in their own modules."""
    import repro.core as core
    for name in ("to_layout", "constrain", "logical_view", "localise",
                 "place", "make_sort_fn", "make_engine_fn",
                 "make_microbench_fn"):
        assert not hasattr(core, name), name
        assert name not in core.__all__, name
    # workload discovery sees only the register_workload registry
    assert set(core.workload_names()) >= {"sort", "engine", "microbench"}


# ---------------------------------------------------------------------------
# acceptance: 8-device host mesh, every policy x backend, via the API only
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_workload_sort_8dev_all_policies_both_backends():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import Homing, Locale, LocalisationPolicy
locale = Locale.auto()
assert locale.axis_size == 8
x0 = jax.random.randint(jax.random.key(0), (1 << 13,), -10**6, 10**6,
                        dtype=jnp.int32)
expect = np.sort(np.asarray(x0))
for backend in ["constraint", "shard_map"]:
    for loc in [True, False]:
        for static in [True, False]:
            for h in [Homing.LOCAL_CHUNKED, Homing.HASH_INTERLEAVED]:
                pol = LocalisationPolicy(loc, static, h)
                fn = locale.with_policy(pol).workload(
                    "sort", backend=backend, local_sort=jnp.sort)
                y = np.asarray(fn(jnp.array(x0)))
                np.testing.assert_array_equal(y, expect,
                    err_msg=f"{backend} {pol.name}")
# put/logical round-trip under real 8-way sharding, both homings
for h in [Homing.LOCAL_CHUNKED, Homing.HASH_INTERLEAVED]:
    l = locale.with_policy(LocalisationPolicy(homing=h))
    hm = l.put(jnp.arange(64, dtype=jnp.int32))
    np.testing.assert_array_equal(np.asarray(hm.logical()), np.arange(64))
print("API_8DEV_OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={**os.environ, "PYTHONPATH": "src"},
                       cwd=ROOT, timeout=900)
    assert "API_8DEV_OK" in r.stdout, r.stdout + r.stderr


# ---------------------------------------------------------------------------
# benchmark surface: --smoke keeps every entry point alive
# ---------------------------------------------------------------------------
def test_benchmarks_smoke_emits_json(tmp_path):
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--smoke", "--skip-local",
         "--out", str(tmp_path)],
        capture_output=True, text=True, cwd=ROOT, timeout=420,
        env={**os.environ, "PYTHONPATH": "src"})
    assert r.returncode == 0, r.stdout + r.stderr
    import json
    import re
    sort = json.load(open(tmp_path / "BENCH_sort.json"))
    micro = json.load(open(tmp_path / "BENCH_microbench.json"))
    assert sort and micro, (sort, micro)
    timed = [rec for rec in sort if rec["us"] is not None]
    assert timed and all(rec["us"] > 0 for rec in timed)
    assert {rec["backend"] for rec in sort} >= {"constraint"}
    assert any(rec["n"] for rec in sort)
    # the --pods grid ran too: BENCH_engine.json carries the per-policy
    # inter/intra-pod exchange-byte totals, and the hierarchical policy
    # moves strictly fewer inter-pod bytes than the flat non-localised path
    engine = json.load(open(tmp_path / "BENCH_engine.json"))

    def inter_total(prefix):
        recs = [r for r in engine
                if prefix in r["name"] and "inter_total=" in r["derived"]]
        assert len(recs) == 1, (prefix, engine)
        return int(re.search(r"inter_total=(\d+)", recs[0]["derived"]).group(1))

    assert inter_total("_hier.") < inter_total("_nonloc-")
    # the serving scheduler ran too: both policies timed, and the recorded
    # acceptance facts hold (bit-identical decode, homed strictly fewer
    # cross-home relayout bytes, homed no more deterministic steps)
    serve = json.load(open(tmp_path / "BENCH_serve.json"))
    assert {r["name"].split("_")[1] for r in serve
            if r["us"] is not None} >= {"fifo", "homed"}
    checks = [r for r in serve if r["name"].startswith("serve_check_")]
    assert checks, serve
    for rec in checks:
        assert "bit_identical=True" in rec["derived"], rec
        assert "relayout_homed_lt_fifo=True" in rec["derived"], rec
        assert "steps_homed_le_fifo=True" in rec["derived"], rec
