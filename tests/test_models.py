"""Per-arch smoke tests (reduced configs) + decode/forward parity.

Parity is the load-bearing correctness test: token-by-token decode through
the KV-cache / SSM-state path must reproduce the full forward pass logits.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, list_configs
from repro.models.model import LM
from repro.models.steps import (init_opt_state, make_loss_fn, make_train_step)
from repro.optim.adamw import AdamW
from repro.sharding.partition import NULL_PLAN

from helpers import ALL_ARCHS, ARCH_PARAMS, build, make_batch


@pytest.mark.parametrize("name", ARCH_PARAMS)
def test_smoke_forward_shapes_no_nans(name):
    cfg, model, params = build(name)
    B, S = 2, 32
    batch = make_batch(cfg, B, S)
    logits, _, aux = model.forward(params, batch)
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ARCH_PARAMS)
def test_smoke_train_step(name):
    cfg, model, params = build(name)
    batch = make_batch(cfg, 2, 32)
    opt = AdamW(lr=1e-3)
    step = jax.jit(make_train_step(model, cfg, NULL_PLAN, opt))
    state = init_opt_state(cfg, opt, params)
    p2, s2, m = step(params, state, batch)
    assert bool(jnp.isfinite(m["loss"]))
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, l: a + float(jnp.abs(l).sum()),
        jax.tree.map(lambda a, b: (a - b).astype(jnp.float32), params, p2), 0.0)
    assert delta > 0


@pytest.mark.slow
def test_train_loss_decreases_dense():
    cfg, model, params = build("qwen3-0.6b")
    batch = make_batch(cfg, 2, 32)
    opt = AdamW(lr=3e-3, weight_decay=0.0)
    step = jax.jit(make_train_step(model, cfg, NULL_PLAN, opt))
    state = init_opt_state(cfg, opt, params)
    losses = []
    for _ in range(20):
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


@pytest.mark.parametrize("name", ARCH_PARAMS)
def test_decode_parity_with_forward(name):
    """Prefill t0 tokens, decode the rest: logits must match full forward."""
    cfg, model, params = build(name)
    B, S, t0 = 2, 32, 16  # t0 is a multiple of the reduced sliding window (16)
    batch = make_batch(cfg, B, S, with_targets=False)
    logits_full, _, _ = model.forward(params, batch)

    def slice_batch(lo, hi):
        out = {}
        for k, v in batch.items():
            if k == "image_embeds":
                out[k] = v
            else:
                out[k] = v[:, lo:hi]
        return out

    last, caches = model.prefill(params, slice_batch(0, t0), max_len=S)
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(logits_full[:, t0 - 1]),
                               rtol=2e-4, atol=2e-4)
    decode = jax.jit(lambda c, b, p: model.decode_step(params, c, b, p))
    for p in range(t0, S):
        step_logits, caches = decode(caches, slice_batch(p, p + 1),
                                     jnp.int32(p))
        np.testing.assert_allclose(
            np.asarray(step_logits), np.asarray(logits_full[:, p]),
            rtol=2e-3, atol=2e-3, err_msg=f"{name} pos {p}")


def test_full_configs_registered_and_sized():
    names = list_configs()
    assert len([n for n in names]) >= 10
    # analytic param counts are in the right ballpark for the named sizes
    expect = {"mixtral-8x7b": 46e9, "deepseek-moe-16b": 16e9, "glm4-9b": 9e9,
              "granite-20b": 20e9, "granite-3-2b": 2.5e9, "mamba2-2.7b": 2.7e9,
              "jamba-1.5-large-398b": 398e9, "llama-3.2-vision-90b": 90e9}
    for n, target in expect.items():
        total = get_config(n).param_counts()["total"]
        assert 0.5 * target < total < 1.8 * target, (n, total, target)


def test_moe_active_params_below_total():
    for n in ["mixtral-8x7b", "deepseek-moe-16b", "jamba-1.5-large-398b"]:
        c = get_config(n).param_counts()
        assert c["active"] < 0.6 * c["total"], (n, c)


@pytest.mark.slow
def test_banded_swa_matches_chunked():
    """Banded O(S*W) SWA == generic chunked attention (mixtral iter1)."""
    import jax
    from repro.models.attention import banded_swa_attention, chunked_attention
    B, S, KV, Gq, hd, W = 2, 128, 2, 2, 16, 32
    ks = jax.random.split(jax.random.key(3), 3)
    q = jax.random.normal(ks[0], (B, S, KV, Gq, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    pos = jnp.arange(S, dtype=jnp.int32)
    a = banded_swa_attention(q, k, v, window=W)
    b = chunked_attention(q, k, v, q_positions=pos, kv_positions=pos,
                          causal=True, window=W, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)
