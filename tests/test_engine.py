"""The shard_map execution engine vs `jnp.sort` — bit-exact, all policies.

Fast tier covers the single-device mesh (padding, dtypes, backend dispatch);
the slow tier runs the real thing: an 8-device host mesh, all four Table-1
policy combinations (localised x homing — the engine *is* the static
mapping, so `static_mapping` has no engine-side analogue), both backends.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BACKENDS, Homing, Locale, LocalisationPolicy,
                        exchange_schedule, pad_to_multiple, pad_value)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_8dev(code: str, timeout: int = 900):
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={**os.environ, "PYTHONPATH": "src"},
                       cwd=ROOT, timeout=timeout)
    return r

POLICIES = [LocalisationPolicy(loc, True, h)
            for loc in (True, False)
            for h in (Homing.LOCAL_CHUNKED, Homing.HASH_INTERLEAVED)]


def _rand(n, dtype, seed=0):
    if jnp.issubdtype(jnp.dtype(dtype), jnp.integer):
        return jax.random.randint(jax.random.key(seed), (n,), -10**6, 10**6,
                                  dtype=dtype)
    return jax.random.normal(jax.random.key(seed), (n,), dtype)


def test_pad_value_covers_core_dtypes():
    assert pad_value(jnp.int32) == jnp.iinfo(jnp.int32).max
    assert pad_value(jnp.float32) == jnp.inf
    assert pad_value(jnp.int16) == jnp.iinfo(jnp.int16).max


@pytest.mark.parametrize("n,m", [(64, 8), (65, 8), (7, 8), (100, 4)])
def test_pad_to_multiple_strips_cleanly(n, m):
    x = _rand(n, jnp.int32)
    xp = pad_to_multiple(x, m)
    assert xp.shape[0] % m == 0 and xp.shape[0] - n < m
    np.testing.assert_array_equal(np.sort(np.asarray(xp))[:n],
                                  np.sort(np.asarray(x)))


def test_unknown_backend_rejected():
    with pytest.raises(ValueError):
        Locale().workload("sort", backend="nope")


# one (n, dtype) config per policy; the fast lane keeps the two policy
# extremes (fully localised, non-localised hash) and the slow 8-device test
# sweeps every policy x dtype x length combination
ENGINE_SINGLE = [pytest.param(p, n, dt, marks=() if i in (0, 3)
                              else (pytest.mark.slow,))
                 for i, (p, (n, dt)) in enumerate(zip(
                     POLICIES, [(512, "int32"), (1000, "float32"),
                                (1000, "int32"), (512, "float32")]))]


@pytest.mark.parametrize("policy,n,dtype", ENGINE_SINGLE,
                         ids=lambda v: getattr(v, "name", v))
def test_engine_single_device_bit_exact(policy, dtype, n):
    """1-device mesh: leaves + local merge path, Pallas bitonic local sort."""
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    x = _rand(n, jnp.dtype(dtype))
    expect = np.sort(np.asarray(x))
    fn = Locale(mesh=mesh, policy=policy).workload("engine", num_workers=8)
    np.testing.assert_array_equal(np.asarray(fn(x)), expect)


def test_constraint_backend_arbitrary_length_padding():
    """Satellite: BIG-padding replaces the old n % m == 0 assert."""
    for n, dtype in ((4097, jnp.int32), (100, jnp.float32)):
        x = _rand(n, dtype)
        expect = np.sort(np.asarray(x))
        fn = Locale().workload("sort", num_workers=8)
        np.testing.assert_array_equal(np.asarray(fn(x)), expect)


def test_sentinel_values_in_data_survive():
    """Real elements equal to the BIG sentinel must not be stripped."""
    for backend in ("constraint", "shard_map"):
        # fresh input per backend: the jitted sorts donate their argument
        x = jnp.asarray([5, jnp.iinfo(jnp.int32).max, -3, 1, 2], jnp.int32)
        expect = np.sort(np.asarray(x))
        fn = Locale().workload("sort", num_workers=4, backend=backend)
        np.testing.assert_array_equal(np.asarray(fn(x)), expect)


@pytest.mark.slow
def test_engine_8dev_all_cases_both_backends():
    """Acceptance: bit-identical to jnp.sort on a >=8-device host mesh."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import Homing, Locale, LocalisationPolicy
locale = Locale.auto()
for backend in ["constraint", "shard_map"]:
    for loc in [True, False]:
        for h in [Homing.LOCAL_CHUNKED, Homing.HASH_INTERLEAVED]:
            for n, dt in [(1 << 13, jnp.int32), (5000, jnp.float32)]:
                if dt == jnp.int32:
                    x = jax.random.randint(jax.random.key(0), (n,), -10**6,
                                           10**6, dtype=dt)
                else:
                    x = jax.random.normal(jax.random.key(0), (n,), dt)
                expect = np.asarray(jnp.sort(x))
                pol = LocalisationPolicy(loc, True, h)
                fn = locale.with_policy(pol).workload("sort", backend=backend)
                y = np.asarray(fn(x))
                np.testing.assert_array_equal(y, expect,
                    err_msg=f"{backend} {pol.name} {n} {dt}")
print("ENGINE_8DEV_OK")
"""
    r = _run_8dev(code)
    assert "ENGINE_8DEV_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_engine_collective_structure_matches_policy():
    """Localised => chunk-sized ppermute merge-split network, log2(m) stages
    with i+1 exchanges each = 6 for m=8 (+ one-shot all-to-all under hash
    homing); non-localised => one all-gather per level. Lowered-HLO check."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.core import Homing, Locale, LocalisationPolicy
from repro.launch.hlo_cost import analyze
locale = Locale.auto()
x = jnp.zeros((1 << 13,), jnp.int32)
def counts(policy):
    fn = locale.with_policy(policy).workload("sort", backend="shard_map")
    return analyze(fn.lower(x).compile().as_text())["collective_counts"]
c = counts(LocalisationPolicy(True, True, Homing.LOCAL_CHUNKED))
assert c.get("collective-permute") == 6 and "all-gather" not in c, c
c = counts(LocalisationPolicy(True, True, Homing.HASH_INTERLEAVED))
assert c.get("collective-permute") == 6 and c.get("all-to-all") == 1, c
c = counts(LocalisationPolicy(False, True, Homing.LOCAL_CHUNKED))
assert c.get("all-gather", 0) >= 4 and "collective-permute" not in c, c
c = counts(LocalisationPolicy(False, True, Homing.HASH_INTERLEAVED))
assert c.get("all-gather", 0) >= 4 and "collective-permute" not in c, c
print("STRUCTURE_OK")
"""
    r = _run_8dev(code)
    assert "STRUCTURE_OK" in r.stdout, r.stdout + r.stderr


# ---------------------------------------------------------------------------
# satellite: NaN-unsafe sentinel padding now fails loudly
# ---------------------------------------------------------------------------
def test_pad_to_multiple_rejects_nan_when_padding():
    x = jnp.asarray([1.0, jnp.nan, 2.0], jnp.float32)
    with pytest.raises(ValueError, match="NaN"):
        pad_to_multiple(x, 8)
    # no padding needed -> pass-through, NaN or not (nothing to corrupt)
    x4 = jnp.asarray([1.0, jnp.nan, 2.0, 0.0], jnp.float32)
    assert pad_to_multiple(x4, 4) is x4


@pytest.mark.parametrize("backend", BACKENDS)
def test_sort_rejects_nan_floats_eagerly(backend):
    """Both float sort paths refuse NaN inputs before tracing/donating."""
    fn = Locale().workload("sort", num_workers=4, backend=backend)
    x = jnp.asarray([3.0, jnp.nan, 1.0, 2.0, 5.0], jnp.float32)
    with pytest.raises(ValueError, match="NaN"):
        fn(x)
    # NaN-free floats (padded and unpadded lengths) still sort bit-exactly
    for n in (5, 8):
        y = jax.random.normal(jax.random.key(0), (n,), jnp.float32)
        expect = np.sort(np.asarray(y))
        np.testing.assert_array_equal(np.asarray(fn(y)), expect)


def test_put_pad_rejects_nan():
    loc = Locale(mesh=jax.make_mesh((1,), ("data",)))
    # axis_size 1 never pads -> accepted; explicit pad granule via the sort
    h = loc.put(jnp.asarray([jnp.nan, 1.0], jnp.float32), pad=True)
    assert h.size == 2
    with pytest.raises(ValueError, match="NaN"):
        pad_to_multiple(jnp.asarray([jnp.nan, 1.0], jnp.float32), 4)


# ---------------------------------------------------------------------------
# satellite: host-mesh shape validation
# ---------------------------------------------------------------------------
def test_make_host_mesh_validates_shape():
    from repro.launch.mesh import make_host_mesh
    n = len(jax.devices())          # 1 in the main test process
    with pytest.raises(ValueError, match=rf"n_model=3.*device count {n}"):
        make_host_mesh(n_model=3)
    with pytest.raises(ValueError, match=rf"needs {5 * n}.*has {n}"):
        make_host_mesh(n_data=5 * n)
    with pytest.raises(ValueError, match="n_pods=2"):
        make_host_mesh(n_pods=2)
    with pytest.raises(ValueError, match="positive int"):
        make_host_mesh(n_model=0)
    m = make_host_mesh()
    assert dict(zip(m.axis_names, m.devices.shape)) == {"data": n, "model": 1}


# ---------------------------------------------------------------------------
# tentpole: hierarchical policy + exchange schedule (fast, analytic)
# ---------------------------------------------------------------------------
def test_hierarchical_policy_factory():
    pol = LocalisationPolicy.hierarchical()
    assert pol.localised and pol.outer == "hash"
    assert pol.homing == Homing.LOCAL_CHUNKED
    assert pol.name.startswith("hier.hash-")
    assert LocalisationPolicy.hierarchical(inner="hash").homing == \
        Homing.HASH_INTERLEAVED
    with pytest.raises(ValueError, match="outer"):
        LocalisationPolicy(outer="nope")
    with pytest.raises(ValueError, match="inner"):
        LocalisationPolicy.hierarchical(inner="nope")


def test_hierarchical_policy_needs_pod_axis():
    """A hierarchical policy on a flat single-axis locale is an error."""
    mesh = jax.make_mesh((1,), ("data",))
    fn = Locale(mesh=mesh,
                policy=LocalisationPolicy.hierarchical()).workload(
                    "engine", num_workers=4)
    with pytest.raises(ValueError, match="pod"):
        fn(jnp.arange(16, dtype=jnp.int32))


def test_exchange_schedule_hier_strictly_fewer_inter_pod_bytes():
    """The acceptance inequality, as pure schedule math on every pod shape."""
    n = 1 << 13
    for sizes in [(2, 4), (2, 2), (4, 2), (2, 1), (4, 4)]:
        hier = exchange_schedule(n, sizes, LocalisationPolicy.hierarchical())
        nonloc = exchange_schedule(
            n, sizes, LocalisationPolicy(False, True, Homing.LOCAL_CHUNKED))
        tot = lambda s, k: sum(r[k] for r in s)
        assert tot(hier, "inter_pod_bytes") < tot(nonloc, "inter_pod_bytes"), \
            sizes
        # intra-pod ppermutes never cross the DCN boundary, and the deep
        # (low-stride) levels are entirely intra-pod
        for r in hier:
            assert r["inter_pod_bytes"] == 0 or r["intra_pod_bytes"] == 0
            if r["op"] == "all_gather":
                assert r["intra_pod_bytes"] == 0
    # single flat axis: everything is "intra-pod" (there is only one pod)
    flat = exchange_schedule(n, (8,), LocalisationPolicy())
    assert all(r["inter_pod_bytes"] == 0 for r in flat)
    assert sum(1 for r in flat if r["op"] == "ppermute") == 6


def test_exchange_schedule_counts_match_network():
    """ppermute count = sum_{i} substages; one gather per top stage (hier)."""
    sched = exchange_schedule(1 << 12, (2, 4),
                              LocalisationPolicy.hierarchical())
    assert sum(1 for r in sched if r["op"] == "all_gather") == 1   # log2(2)
    assert sum(1 for r in sched if r["op"] == "ppermute") == 5     # 1+2+2
    flat = exchange_schedule(1 << 12, (2, 4), LocalisationPolicy())
    assert sum(1 for r in flat if r["op"] == "ppermute") == 6      # 1+2+3
    # hash input homing adds exactly one relayout all_to_all up front
    hashed = exchange_schedule(1 << 12, (2, 4),
                               LocalisationPolicy.hierarchical(inner="hash"))
    assert hashed[0]["op"] == "all_to_all" and hashed[0]["level"] == 0


# ---------------------------------------------------------------------------
# tentpole: emulated-pod meshes, bit-exact + HLO structure (slow subprocess)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_engine_pod_meshes_bit_exact_all_policies():
    """Acceptance: (2,2,2) and (2,4,1) emulated pods, hierarchical + flat
    policies, shard_map engine vs jnp.sort; constraint backend spot-checked
    on a padded length (the GSPMD mis-partition regression)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import Homing, Locale, LocalisationPolicy
from repro.launch.mesh import make_host_mesh
for shape in [(2, 2, 2), (2, 4, 1)]:
    mesh = make_host_mesh(n_pods=shape[0], n_data=shape[1], n_model=shape[2])
    locale = Locale(mesh=mesh, axis=("pod", "data"))
    pols = [LocalisationPolicy.hierarchical(),
            LocalisationPolicy.hierarchical(inner="hash"),
            LocalisationPolicy(True, True, Homing.LOCAL_CHUNKED),
            LocalisationPolicy(True, True, Homing.HASH_INTERLEAVED),
            LocalisationPolicy(False, True, Homing.LOCAL_CHUNKED),
            LocalisationPolicy(False, True, Homing.HASH_INTERLEAVED)]
    for pol in pols:
        for n, dt in [(1 << 13, jnp.int32), (5000, jnp.float32)]:
            if dt == jnp.int32:
                x = jax.random.randint(jax.random.key(0), (n,), -10**6,
                                       10**6, dtype=dt)
            else:
                x = jax.random.normal(jax.random.key(0), (n,), dt)
            expect = np.asarray(jnp.sort(x))
            fn = locale.with_policy(pol).workload("sort", backend="shard_map",
                                                  local_sort=jnp.sort)
            np.testing.assert_array_equal(np.asarray(fn(x)), expect,
                err_msg=f"shard_map {shape} {pol.name} {n}")
    # constraint backend on the pod mesh: a padded length used to come back
    # doubled (GSPMD partitioned concatenate/scatter on a mesh with a >1
    # unrelated axis); eager padding + the gather-form merge fixed it
    for pol in [LocalisationPolicy.hierarchical(),
                LocalisationPolicy(True, True, Homing.LOCAL_CHUNKED),
                LocalisationPolicy(False, True, Homing.HASH_INTERLEAVED)]:
        x = jax.random.randint(jax.random.key(2), (4097,), -10**6, 10**6,
                               dtype=jnp.int32)
        expect = np.sort(np.asarray(x))
        fn = locale.with_policy(pol).workload("sort", backend="constraint")
        np.testing.assert_array_equal(np.asarray(fn(x)), expect,
            err_msg=f"constraint {shape} {pol.name}")
    print("POD_MESH_OK", shape)
# bypassing the eager-padding entry points with a non-granular length on a
# mesh with a >1 unrelated axis must fail loudly at trace time, not return
# silently-doubled values (check_pad_outside_trace)
from functools import partial
from repro.core.sort import distributed_merge_sort
from repro.core.engine import shard_map_sort
mesh = make_host_mesh(n_pods=2, n_data=2, n_model=2)
for raw in [partial(distributed_merge_sort, mesh=mesh, axis=("pod", "data")),
            partial(shard_map_sort, mesh=mesh, axis=("pod", "data"))]:
    try:
        jax.jit(raw)(jnp.zeros((4097,), jnp.int32))
        raise SystemExit("in-trace pad on an unsafe mesh did not raise")
    except ValueError as e:
        assert "pad_to_multiple" in str(e), e
print("PAD_GUARD_OK")
"""
    r = _run_8dev(code)
    assert r.stdout.count("POD_MESH_OK") == 2, r.stdout + r.stderr
    assert "PAD_GUARD_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_engine_pod_collective_structure():
    """Lowered-HLO proof of the two distance classes on a (2,4,1) mesh:
    hierarchical => 5 intra-pod ppermutes + ONE pod-axis all_gather (the
    only DCN collective); flat localised => 6 pairwise ppermutes, no
    gather; non-localised => one all_gather per level, no ppermutes.  The
    counts must agree with exchange_schedule, which the benchmark reports."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from collections import Counter
from repro.core import Homing, Locale, LocalisationPolicy, exchange_schedule
from repro.launch.hlo_cost import analyze
from repro.launch.mesh import make_host_mesh
mesh = make_host_mesh(n_pods=2, n_data=4, n_model=1)
locale = Locale(mesh=mesh, axis=("pod", "data"))
x = jnp.zeros((1 << 13,), jnp.int32)
def counts(policy):
    fn = locale.with_policy(policy).workload("sort", backend="shard_map")
    return analyze(fn.lower(x).compile().as_text())["collective_counts"]
def sched_counts(policy):
    ops = Counter(r["op"] for r in exchange_schedule(1 << 13, (2, 4), policy))
    return {"collective-permute": ops.get("ppermute", 0),
            "all-gather": ops.get("all_gather", 0),
            "all-to-all": ops.get("all_to_all", 0)}
hier = LocalisationPolicy.hierarchical()
c = counts(hier)
assert c.get("collective-permute") == 5 and c.get("all-gather") == 1, c
flat = LocalisationPolicy(True, True, Homing.LOCAL_CHUNKED)
c2 = counts(flat)
assert c2.get("collective-permute") == 6 and "all-gather" not in c2, c2
hh = LocalisationPolicy.hierarchical(inner="hash")
c3 = counts(hh)
assert c3.get("all-to-all") == 1 and c3.get("collective-permute") == 5 \
    and c3.get("all-gather") == 1, c3
nl = LocalisationPolicy(False, True, Homing.LOCAL_CHUNKED)
c4 = counts(nl)
assert c4.get("all-gather", 0) >= 4 and "collective-permute" not in c4, c4
assert sched_counts(nl)["all-gather"] == 4
# the analytic schedule the benchmark emits matches the lowered HLO of the
# localised variants exactly
for pol, c in [(hier, c), (flat, c2), (hh, c3)]:
    sc = sched_counts(pol)
    for k, v in sc.items():
        assert c.get(k, 0) == v, (pol.name, k, v, c)
print("POD_STRUCTURE_OK")
"""
    r = _run_8dev(code)
    assert "POD_STRUCTURE_OK" in r.stdout, r.stdout + r.stderr
