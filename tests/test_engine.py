"""The shard_map execution engine vs `jnp.sort` — bit-exact, all policies.

Fast tier covers the single-device mesh (padding, dtypes, backend dispatch);
the slow tier runs the real thing: an 8-device host mesh, all four Table-1
policy combinations (localised x homing — the engine *is* the static
mapping, so `static_mapping` has no engine-side analogue), both backends.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Homing, Locale, LocalisationPolicy, pad_to_multiple,
                        pad_value)

POLICIES = [LocalisationPolicy(loc, True, h)
            for loc in (True, False)
            for h in (Homing.LOCAL_CHUNKED, Homing.HASH_INTERLEAVED)]


def _rand(n, dtype, seed=0):
    if jnp.issubdtype(jnp.dtype(dtype), jnp.integer):
        return jax.random.randint(jax.random.key(seed), (n,), -10**6, 10**6,
                                  dtype=dtype)
    return jax.random.normal(jax.random.key(seed), (n,), dtype)


def test_pad_value_covers_core_dtypes():
    assert pad_value(jnp.int32) == jnp.iinfo(jnp.int32).max
    assert pad_value(jnp.float32) == jnp.inf
    assert pad_value(jnp.int16) == jnp.iinfo(jnp.int16).max


@pytest.mark.parametrize("n,m", [(64, 8), (65, 8), (7, 8), (100, 4)])
def test_pad_to_multiple_strips_cleanly(n, m):
    x = _rand(n, jnp.int32)
    xp = pad_to_multiple(x, m)
    assert xp.shape[0] % m == 0 and xp.shape[0] - n < m
    np.testing.assert_array_equal(np.sort(np.asarray(xp))[:n],
                                  np.sort(np.asarray(x)))


def test_unknown_backend_rejected():
    with pytest.raises(ValueError):
        Locale().workload("sort", backend="nope")


# one (n, dtype) config per policy; the fast lane keeps the two policy
# extremes (fully localised, non-localised hash) and the slow 8-device test
# sweeps every policy x dtype x length combination
ENGINE_SINGLE = [pytest.param(p, n, dt, marks=() if i in (0, 3)
                              else (pytest.mark.slow,))
                 for i, (p, (n, dt)) in enumerate(zip(
                     POLICIES, [(512, "int32"), (1000, "float32"),
                                (1000, "int32"), (512, "float32")]))]


@pytest.mark.parametrize("policy,n,dtype", ENGINE_SINGLE,
                         ids=lambda v: getattr(v, "name", v))
def test_engine_single_device_bit_exact(policy, dtype, n):
    """1-device mesh: leaves + local merge path, Pallas bitonic local sort."""
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    x = _rand(n, jnp.dtype(dtype))
    expect = np.sort(np.asarray(x))
    fn = Locale(mesh=mesh, policy=policy).workload("engine", num_workers=8)
    np.testing.assert_array_equal(np.asarray(fn(x)), expect)


def test_constraint_backend_arbitrary_length_padding():
    """Satellite: BIG-padding replaces the old n % m == 0 assert."""
    for n, dtype in ((4097, jnp.int32), (100, jnp.float32)):
        x = _rand(n, dtype)
        expect = np.sort(np.asarray(x))
        fn = Locale().workload("sort", num_workers=8)
        np.testing.assert_array_equal(np.asarray(fn(x)), expect)


def test_sentinel_values_in_data_survive():
    """Real elements equal to the BIG sentinel must not be stripped."""
    for backend in ("constraint", "shard_map"):
        # fresh input per backend: the jitted sorts donate their argument
        x = jnp.asarray([5, jnp.iinfo(jnp.int32).max, -3, 1, 2], jnp.int32)
        expect = np.sort(np.asarray(x))
        fn = Locale().workload("sort", num_workers=4, backend=backend)
        np.testing.assert_array_equal(np.asarray(fn(x)), expect)


@pytest.mark.slow
def test_engine_8dev_all_cases_both_backends():
    """Acceptance: bit-identical to jnp.sort on a >=8-device host mesh."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import Homing, Locale, LocalisationPolicy
locale = Locale.auto()
for backend in ["constraint", "shard_map"]:
    for loc in [True, False]:
        for h in [Homing.LOCAL_CHUNKED, Homing.HASH_INTERLEAVED]:
            for n, dt in [(1 << 13, jnp.int32), (5000, jnp.float32)]:
                if dt == jnp.int32:
                    x = jax.random.randint(jax.random.key(0), (n,), -10**6,
                                           10**6, dtype=dt)
                else:
                    x = jax.random.normal(jax.random.key(0), (n,), dt)
                expect = np.asarray(jnp.sort(x))
                pol = LocalisationPolicy(loc, True, h)
                fn = locale.with_policy(pol).workload("sort", backend=backend)
                y = np.asarray(fn(x))
                np.testing.assert_array_equal(y, expect,
                    err_msg=f"{backend} {pol.name} {n} {dt}")
print("ENGINE_8DEV_OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={**os.environ, "PYTHONPATH": "src"},
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), timeout=900)
    assert "ENGINE_8DEV_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_engine_collective_structure_matches_policy():
    """Localised => chunk-sized ppermute merge-split network, log2(m) stages
    with i+1 exchanges each = 6 for m=8 (+ one-shot all-to-all under hash
    homing); non-localised => one all-gather per level. Lowered-HLO check."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.core import Homing, Locale, LocalisationPolicy
from repro.launch.hlo_cost import analyze
locale = Locale.auto()
x = jnp.zeros((1 << 13,), jnp.int32)
def counts(policy):
    fn = locale.with_policy(policy).workload("sort", backend="shard_map")
    return analyze(fn.lower(x).compile().as_text())["collective_counts"]
c = counts(LocalisationPolicy(True, True, Homing.LOCAL_CHUNKED))
assert c.get("collective-permute") == 6 and "all-gather" not in c, c
c = counts(LocalisationPolicy(True, True, Homing.HASH_INTERLEAVED))
assert c.get("collective-permute") == 6 and c.get("all-to-all") == 1, c
c = counts(LocalisationPolicy(False, True, Homing.LOCAL_CHUNKED))
assert c.get("all-gather", 0) >= 4 and "collective-permute" not in c, c
c = counts(LocalisationPolicy(False, True, Homing.HASH_INTERLEAVED))
assert c.get("all-gather", 0) >= 4 and "collective-permute" not in c, c
print("STRUCTURE_OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={**os.environ, "PYTHONPATH": "src"},
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), timeout=900)
    assert "STRUCTURE_OK" in r.stdout, r.stdout + r.stderr
