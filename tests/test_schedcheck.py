"""schedcheck + livecheck: every R9 mutant fixture is provably flagged
with its minimal witness trace, the clean scheduler certifies over the
full small-config lattice, and the R10/R11 known-bad lowered fixtures are
flagged while real programs run clean (the CLI sweep in test_homecheck.py
covers the lowered workloads; mirror of the test_kernelcheck.py layout).

Everything here is pure python over the transition functions and HLO-text
fixtures — no devices, no lowering — so the exhaustive certification runs
in-process.
"""
import dataclasses

import pytest

from repro.analysis.findings import Report, Severity
from repro.analysis.fixtures import (MUTANT_INVARIANT,
                                     branch_mismatch_module,
                                     consistent_branches_module,
                                     data_dependent_loop_module,
                                     hbm_hog_module, mutant_scheduler)
from repro.analysis.hlo_facts import liveness
from repro.analysis.livecheck import (collective_signature,
                                      r10_hbm_live_range,
                                      r11_collective_control_flow)
from repro.analysis.schedcheck import (DEFAULT_LATTICE, FAST_LATTICE,
                                       certify, certify_lattice,
                                       r9_scheduler_certification)
from repro.launch.hlo_cost import parse_module
from repro.runtime.scheduler import (MUTATIONS, SchedConfig, Served,
                                     complete_t, initial_state)


# ---------------------------------------------------------------------------
# R9: the clean scheduler certifies over the FULL small-config lattice
# ---------------------------------------------------------------------------
def test_full_lattice_certifies_clean():
    cert = certify_lattice(DEFAULT_LATTICE)
    assert set(cert) == {e.name for e in DEFAULT_LATTICE}
    for name, rec in cert.items():
        assert rec["witness"] is None, (
            f"{name}: {rec['witness'].format()}")
        assert rec["states"] > 0
    # the per-target fast corner is a strict subset of the certificate
    assert {e.name for e in FAST_LATTICE} < {e.name for e in DEFAULT_LATTICE}
    # memoized: the CLI/rule path pays for the exploration once per process
    assert certify_lattice(DEFAULT_LATTICE) is cert


def test_r9_rule_reports_certificate_note():
    rep = Report(target="r9-clean")
    r9_scheduler_certification(rep, FAST_LATTICE)
    assert rep.clean, rep.format()
    assert any("scheduler certified" in n for n in rep.notes)


# ---------------------------------------------------------------------------
# R9 mutants: each committed known-bad transition variant has a witness
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mutation", MUTATIONS)
def test_mutant_scheduler_flagged_with_minimal_witness(mutation):
    entry = mutant_scheduler(mutation)
    witness, states = certify(entry)
    assert witness is not None, f"{entry.name}: mutant certified clean"
    assert witness.invariant == MUTANT_INVARIANT[mutation]
    assert witness.events, "witness must carry the violating event script"
    assert witness.config == entry.name
    formatted = witness.format()
    assert witness.invariant in formatted and "after [" in formatted
    assert 0 < states <= 200_000


def test_r9_rule_errors_carry_the_witness():
    rep = Report(target="r9-mutant")
    r9_scheduler_certification(rep, (mutant_scheduler("drop_charge"),))
    errs = [f for f in rep.errors if f.rule == "R9"]
    assert errs, rep.format()
    assert "I1-uncharged-move" in errs[0].message
    assert "after [" in errs[0].message        # the event script rides along


def test_mutant_scheduler_rejects_unknown_mutation():
    with pytest.raises(ValueError, match="unknown scheduler mutation"):
        mutant_scheduler("teleport")


# ---------------------------------------------------------------------------
# the eviction path: one stable sort, oldest-first prefix, never migrates
# (pins the sort-once complete_t behaviour the R9 audit replays)
# ---------------------------------------------------------------------------
def test_complete_t_evicts_lru_prefix_in_one_stable_sort():
    big = SchedConfig(policy="homed", n_slots=2, owners=(0, 0),
                      bytes_per_token=2, session_capacity=8)
    st = initial_state(big)
    for i, t in enumerate([3.0, 1.0, 4.0, 2.0]):      # scrambled last_used
        st, ev = complete_t(big, st, [Served(i, f"s{i}", 0, 4)], now=t)
        assert ev == ()                                # under capacity
    small = dataclasses.replace(big, session_capacity=2)
    st, evicted = complete_t(small, st, [Served(9, "new", 0, 4)], now=9.0)
    # the over-capacity prefix leaves oldest-first, on its own home
    assert [b.session for b in evicted] == ["s1", "s3", "s0"]
    assert all(b.home == 0 for b in evicted)
    assert sorted(b.last_used for b in evicted) == [1.0, 2.0, 3.0]


# ---------------------------------------------------------------------------
# R10: HBM live-range gate — hog fixture flagged, generous ceiling clean
# ---------------------------------------------------------------------------
def test_r10_hog_fixture_exceeds_32mib_ceiling():
    rep = Report(target="r10-hog")
    r10_hbm_live_range(rep, hbm_hog_module(), ceiling=32 * 2**20)
    errs = [f for f in rep.errors if f.rule == "R10"]
    assert errs, rep.format()
    assert errs[0].actual_bytes == 4 * 16 * 2**20      # all four buffers live
    assert "per-device ceiling" in errs[0].message
    assert "largest at peak" in errs[0].message

    rep2 = Report(target="r10-ok")
    r10_hbm_live_range(rep2, hbm_hog_module(), ceiling=128 * 2**20)
    assert rep2.clean, rep2.format()
    assert any("headroom" in n for n in rep2.notes)


def test_r10_liveness_scan_facts():
    live = liveness(hbm_hog_module())
    assert live["peak_bytes"] == 4 * 16 * 2**20
    assert live["param_bytes"] == 16 * 2**20
    assert live["n_buffers"] == 4
    assert live["live_at_peak"]


def test_r10_memory_stats_tightens_the_scan():
    # compiler-reported stats dominate the syntactic scan when larger
    stats = {"argument_size_in_bytes": 48 * 2**20,
             "output_size_in_bytes": 16 * 2**20,
             "temp_size_in_bytes": 8 * 2**20}
    rep = Report(target="r10-stats")
    r10_hbm_live_range(rep, hbm_hog_module(), ceiling=68 * 2**20,
                       memory_stats=stats)
    errs = [f for f in rep.errors if f.rule == "R10"]
    assert errs and errs[0].actual_bytes == 72 * 2**20
    assert "xla memory_analysis" in errs[0].message


# ---------------------------------------------------------------------------
# R11: collectives under data-dependent control flow
# ---------------------------------------------------------------------------
def test_r11_branch_mismatch_is_error():
    rep = Report(target="r11-mismatch")
    r11_collective_control_flow(rep, branch_mismatch_module())
    errs = [f for f in rep.errors if f.rule == "R11"]
    assert errs, rep.format()
    assert "differ across branches" in errs[0].message
    assert "deadlock" in errs[0].message


def test_r11_data_dependent_loop_is_warn_not_error():
    rep = Report(target="r11-loop")
    r11_collective_control_flow(rep, data_dependent_loop_module())
    assert not rep.errors, rep.format()
    warns = [f for f in rep.findings
             if f.rule == "R11" and f.severity == Severity.WARN]
    assert warns and "trip count" in warns[0].message


def test_r11_consistent_branches_are_clean():
    rep = Report(target="r11-clean")
    r11_collective_control_flow(rep, consistent_branches_module())
    assert rep.clean, rep.format()
    assert any("collective-control-flow ok" in n for n in rep.notes)


def test_collective_signature_orders_kind_and_bytes():
    comps = parse_module(branch_mismatch_module())
    branch_sigs = {name: collective_signature(comps, name)
                   for name in comps if name != "__entry__"}
    with_ar = [s for s in branch_sigs.values() if s]
    assert with_ar and with_ar[0][0][0] == "all-reduce"
    assert with_ar[0][0][1] > 0
