"""Substrate tests: data determinism, checkpoint atomicity+elasticity,
optimizer correctness, gradient compression properties."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # offline CI image: deterministic shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.checkpoint import latest_step, restore, save
from repro.data import SyntheticLM
from repro.optim import AdamW
from repro.optim.compression import (compressed_psum, dequantize,
                                     error_feedback_update, quantize)

from helpers import build, make_batch, tiny


def test_data_deterministic_across_restarts():
    cfg = tiny("qwen3-0.6b")
    a = SyntheticLM(cfg, 4, 32, seed=7).batch(13)
    b = SyntheticLM(cfg, 4, 32, seed=7).batch(13)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    c = SyntheticLM(cfg, 4, 32, seed=8).batch(13)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_pipeline_striped_matches_host_oracle():
    """Striped generation (each leaf born per-device via Locale.make) must
    reproduce the build-on-host-then-place oracle bit-exactly, for every
    batch family: tokens, frame embeddings, and VLM image embeddings."""
    for arch in ("qwen3-0.6b", "musicgen-medium", "llama-3.2-vision-90b"):
        cfg = tiny(arch)
        a = SyntheticLM(cfg, 4, 16, seed=11, striped=True).batch(3)
        b = SyntheticLM(cfg, 4, 16, seed=11, striped=False).batch(3)
        assert set(a) == set(b), (arch, set(a), set(b))
        for k in a:
            np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]),
                                          err_msg=f"{arch}:{k}")


@pytest.mark.slow
def test_pipeline_striped_matches_host_on_mesh():
    """On a real multi-device mesh the striped batch must match the host
    oracle bit-exactly *and* land under the same chunk-contiguous sharding
    (rows born on their home device, never resharded)."""
    import subprocess
    import sys
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax
from repro.configs import get_config, reduce_config
from repro.data import SyntheticLM
for arch in ("qwen3-0.6b", "musicgen-medium", "llama-3.2-vision-90b"):
    cfg = reduce_config(get_config(arch))
    mesh = jax.make_mesh((4,), ("data",))
    a = SyntheticLM(cfg, 8, 16, seed=5, mesh=mesh, striped=True).batch(2)
    b = SyntheticLM(cfg, 8, 16, seed=5, mesh=mesh, striped=False).batch(2)
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))
        assert a[k].sharding == b[k].sharding, (k, a[k].sharding)
print("STRIPED_PIPELINE_OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600,
                       env={**os.environ, "PYTHONPATH": "src"},
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "STRIPED_PIPELINE_OK" in r.stdout, r.stdout + r.stderr


def test_checkpoint_roundtrip_and_gc(tmp_path):
    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.bfloat16),
                  "step": jnp.int32(7)}}
    for s in [1, 2, 3, 4, 5]:
        save(str(tmp_path), s, tree, keep_last=2)
    assert latest_step(str(tmp_path)) == 5
    assert sorted(d for d in os.listdir(tmp_path) if d.startswith("step_")) \
        == ["step_00000004", "step_00000005"]
    out = restore(str(tmp_path), 5, jax.tree.map(jnp.zeros_like, tree))
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_checkpoint_atomic_no_partial_visible(tmp_path):
    # a tmp.<step> dir must never be picked up by latest_step
    os.makedirs(tmp_path / "tmp.9")
    assert latest_step(str(tmp_path)) is None


def test_adamw_matches_numpy_reference():
    opt = AdamW(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                clip_norm=0.0)
    p = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    g = {"w": jnp.asarray([0.1, 0.2, -0.3])}
    st_ = opt.init(p)
    p1, st1, _ = opt.update(g, st_, p)
    m = 0.1 * np.asarray(g["w"])
    v = 0.01 * np.asarray(g["w"]) ** 2
    upd = (m / (1 - 0.9)) / (np.sqrt(v / (1 - 0.99)) + 1e-8)
    np.testing.assert_allclose(np.asarray(p1["w"]),
                               np.asarray(p["w"]) - 0.1 * upd, rtol=1e-5)


@given(st.integers(0, 2**31 - 1), st.sampled_from([32, 100, 257]))
@settings(max_examples=25, deadline=None)
def test_quantize_roundtrip_bounded_error(seed, n):
    x = jax.random.normal(jax.random.key(seed), (n,)) * 10
    q, s = quantize(x, block=64)
    y = dequantize(q, s, x.shape)
    err = np.abs(np.asarray(x) - np.asarray(y))
    bound = np.repeat(np.asarray(s), 64)[:n] * 0.5 + 1e-6
    assert (err <= bound).all()


def test_error_feedback_removes_bias():
    """Constant grad + EF: accumulated dequantised sum converges to true sum."""
    g = jnp.full((64,), 0.0123, jnp.float32)
    ef = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    for _ in range(50):
        deq, ef = error_feedback_update(g, ef, block=64)
        total = total + deq
    np.testing.assert_allclose(np.asarray(total), 50 * 0.0123,
                               rtol=5e-3)


@pytest.mark.slow
def test_compressed_psum_multidevice():
    import subprocess, sys
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from functools import partial
from repro.optim.compression import compressed_psum
from jax.experimental.shard_map import shard_map
mesh = jax.make_mesh((4,), ("d",))
x = jax.random.normal(jax.random.key(0), (4, 256)) * 3
f = jax.jit(shard_map(partial(compressed_psum, axis_name="d"),
    mesh=mesh, in_specs=P("d"), out_specs=P(None), check_rep=False))
out = np.asarray(f(x))[0]
expect = np.asarray(x).sum(0)
err = np.abs(out - expect).max()
assert err < 0.25, err  # <= n_shards * max|x|/254 analytic bound
print("PSUM_OK", err)
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={**os.environ, "PYTHONPATH": "src"},
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), timeout=300)
    assert "PSUM_OK" in r.stdout, r.stdout + r.stderr
