"""homecheck: every rule R1-R4 provably fires on a committed fixture, and
the analyzer runs clean over every registered workload x policy x backend
(R5-R8 fixtures and the network-certification sweep: test_kernelcheck.py).

The R1/R2 fixtures need a partitioned lowering, so they run in one
8-device subprocess; R3/R4 and the Report API are single-device and run
in-process.  The clean sweep drives the real CLI (exit status included) —
one subprocess per mesh shape, each covering every policy via
``--policy all``.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

import repro.core  # noqa: F401  (must precede repro.kernels imports)
from repro.analysis import (Finding, Report, Severity, check_artifacts,
                            summarize)
from repro.analysis.rules import r3_vmem_budget
from repro.analysis.vmem import pallas_footprints
from repro.core import Homing, Locale, LocalisationPolicy
from repro.kernels import VMEM_BYTES_PER_CORE
from repro.kernels.local_sort import local_sort

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, timeout: int = 420) -> str:
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={**os.environ, "PYTHONPATH": "src"},
                       cwd=ROOT, timeout=timeout)
    assert r.returncode == 0, r.stdout + r.stderr
    return r.stdout


# ---------------------------------------------------------------------------
# findings/report mechanics
# ---------------------------------------------------------------------------
def test_report_clean_errors_suppress_and_summarize():
    rep = Report(target="t")
    assert rep.clean and not rep.errors
    rep.add(Finding("R4", Severity.WARN, "parameter"))
    rep.add(Finding("R1", Severity.ERROR, "all-to-all",
                    predicted_bytes=0.0, actual_bytes=128.0))
    assert not rep.clean
    assert [f.rule for f in rep.errors] == ["R1"]
    assert "[R1 ERROR] all-to-all" in rep.format()
    rep.suppress(["R4"])
    assert rep.suppressed == ["R4"]
    assert [f.rule for f in rep.findings] == ["R1"]
    assert summarize([rep, Report(target="u")]) == (1, 1)


# ---------------------------------------------------------------------------
# R3 fixture: an oversized local_sort chunk cannot fit per-core VMEM
# ---------------------------------------------------------------------------
def test_r3_vmem_budget_flags_oversized_local_sort_chunk():
    big = jax.ShapeDtypeStruct((1, 1 << 23), jnp.float32)   # 32 MiB row
    jx = jax.make_jaxpr(lambda v: local_sort(v))(big)       # trace only
    rep = Report(target="r3-fixture")
    r3_vmem_budget(rep, pallas_footprints(jx), VMEM_BYTES_PER_CORE)
    errs = rep.errors
    assert errs and all(f.rule == "R3" for f in errs), rep.format()
    assert errs[0].actual_bytes > VMEM_BYTES_PER_CORE

    ok = jax.ShapeDtypeStruct((4, 1 << 10), jnp.float32)    # 4 KiB rows
    rep2 = Report(target="r3-small")
    r3_vmem_budget(rep2, pallas_footprints(
        jax.make_jaxpr(lambda v: local_sort(v))(ok)), VMEM_BYTES_PER_CORE)
    assert rep2.clean, rep2.format()


# ---------------------------------------------------------------------------
# R4 fixture: a large step-carried buffer that is not donated
# ---------------------------------------------------------------------------
def test_r4_donation_audit_flags_then_clean_when_donated():
    x = jnp.zeros((1 << 19,), jnp.float32)                  # 2 MiB
    step = lambda b: b * 2.0
    hlo = jax.jit(step).lower(x).compile().as_text()
    rep = check_artifacts("r4-fixture", hlo)
    assert any(f.rule == "R4" and f.severity == Severity.WARN
               for f in rep.findings), rep.format()
    assert not rep.clean and not rep.errors     # WARN dirties, not ERROR

    donated = jax.jit(step, donate_argnums=(0,)).lower(x).compile().as_text()
    assert check_artifacts("r4-donated", donated).clean

    sup = check_artifacts("r4-suppressed", hlo, suppress=("R4",))
    assert sup.clean and sup.suppressed == ["R4"]


# ---------------------------------------------------------------------------
# R1 + R2 fixtures: need a multi-device partitioned lowering
# ---------------------------------------------------------------------------
R1_R2_FIXTURES = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.analysis import check_artifacts
from repro.core import Homing, Locale, LocalisationPolicy, collective_census
from repro.core.engine import engine_granule
from repro.launch.mesh import make_host_mesh

# R2: the PR 3 GSPMD miscompile class, kept as a fixture.  An in-jit
# sentinel concatenate + chunked constraint on a mesh with a >1 unrelated
# "model" axis makes GSPMD insert an all-reduce spanning ALL axes — padded
# elements arrive summed across "model".  homecheck must flag it.
mesh = make_host_mesh(n_pods=2, n_data=2, n_model=2)

def leaky(x):
    pad = jnp.full((31,), jnp.iinfo(jnp.int32).max, jnp.int32)
    y = jnp.concatenate([x, pad])
    y = jax.lax.with_sharding_constraint(
        y, NamedSharding(mesh, P(("pod", "data"))))
    return jnp.sort(y)

hlo = jax.jit(leaky).lower(jnp.zeros((4065,), jnp.int32)).compile().as_text()
rep = check_artifacts("r2-fixture", hlo, mesh=mesh,
                      allowed_axes=("pod", "data"))
assert any(f.rule == "R2" for f in rep.errors), rep.format(verbose=True)
assert any("model" in f.message for f in rep.errors)
print("R2_FLAGGED")

# R1: lower the hash-interleaved engine, then diff it against the budget
# for the *chunked* policy — the hash pre-exchange all-to-all is unbudgeted.
flat = make_host_mesh(n_data=8, n_model=1)
loc = Locale(mesh=flat, axis="data",
             policy=LocalisationPolicy(homing=Homing.HASH_INTERLEAVED))
g = engine_granule(8, None, True)
n = ((1 << 13) + g - 1) // g * g
fn = loc.workload("sort", backend="shard_map")
hlo = fn.lower(jnp.arange(n, dtype=jnp.int32)).compile().as_text()
wrong = collective_census(n, (8,), LocalisationPolicy())
rep = check_artifacts("r1-fixture", hlo, predicted=wrong, mesh=flat,
                      allowed_axes=("data",))
assert any(f.rule == "R1" and "unbudgeted" in f.message
           for f in rep.errors), rep.format(verbose=True)

# the matching budget must be clean (same artifacts, right policy)
right = collective_census(
    n, (8,), LocalisationPolicy(homing=Homing.HASH_INTERLEAVED))
assert check_artifacts("r1-match", hlo, predicted=right, mesh=flat,
                       allowed_axes=("data",)).clean
print("R1_FLAGGED")
"""


def test_r1_r2_fixtures_flag_committed_patterns():
    out = _run(R1_R2_FIXTURES)
    assert "R2_FLAGGED" in out and "R1_FLAGGED" in out


# ---------------------------------------------------------------------------
# Locale.check(): the in-API hook (degenerate single-device locale)
# ---------------------------------------------------------------------------
def test_locale_check_api_single_device():
    for policy in (LocalisationPolicy(),
                   LocalisationPolicy(homing=Homing.HASH_INTERLEAVED)):
        rep = Locale(mesh=None, policy=policy).check(
            "sort", backend="constraint")
        assert rep.clean, rep.format(verbose=True)
    rep = Locale(mesh=None).check("microbench", reps=2)
    assert rep.clean, rep.format(verbose=True)
    assert rep.target == "microbench"


# ---------------------------------------------------------------------------
# acceptance sweep: every workload x {flat, hierarchical} x both backends
# runs homecheck-clean through the real CLI (exit status 0)
# ---------------------------------------------------------------------------
SWEEP = [
    ("flat-all-policies",
     ["--workload", "all", "--pods", "1x4", "--policy", "all"]),
    ("hier-all-policies",
     ["--workload", "all", "--pods", "2x2x2", "--policy", "all"]),
    ("flat-constraint", ["--workload", "sort", "--pods", "1x4",
                         "--backend", "constraint"]),
    ("engine-hier", ["--workload", "engine", "--pods", "2x2",
                     "--policy", "hier"]),
    ("flat-new-rules", ["--workload", "sort", "--pods", "1x4",
                        "--policy", "all", "--rules", "R5", "R6", "R7",
                        "R8"]),
    ("serve-r9r10r11", ["--workload", "serve", "--pods", "1x4",
                        "--rules", "r9,r10,r11"]),
]


@pytest.mark.parametrize("name,argv", SWEEP, ids=[s[0] for s in SWEEP])
def test_homecheck_cli_sweep_clean(name, argv):
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.homecheck", *argv],
        capture_output=True, text=True, cwd=ROOT, timeout=420,
        env={**os.environ, "PYTHONPATH": "src"})
    assert r.returncode == 0, r.stdout + r.stderr
    assert "0 finding(s), 0 error(s)" in r.stdout, r.stdout
    if any("r9" in a.lower() for a in argv):
        # the full-lattice scheduler certificate prints with the sweep
        assert "R9 certificate [scheduler]" in r.stdout, r.stdout
