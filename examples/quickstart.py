"""Quickstart: the paper's technique through the `Locale` API.

Run:  PYTHONPATH=src python examples/quickstart.py
(For a real multi-worker demo: XLA_FLAGS=--xla_force_host_platform_device_count=8)
"""
import jax
import jax.numpy as jnp

from repro.core import Homing, Locale, LocalisationPolicy

# One object carries the whole placement decision: (mesh, axis, policy).
locale = Locale.auto()                                        # all devices

# --- the paper's Table-1 extremes ---
localised = locale.with_policy(LocalisationPolicy(
    localised=True, static_mapping=True, homing=Homing.LOCAL_CHUNKED))  # Case 8
conventional = locale.with_policy(LocalisationPolicy(
    localised=False, static_mapping=True, homing=Homing.HASH_INTERLEAVED))  # Case 3

x = jax.random.randint(jax.random.key(0), (1 << 18,), 0, 1 << 30, jnp.int32)
for name, loc in [("localised(case8)", localised),
                  ("conventional(case3)", conventional)]:
    sort = loc.workload("sort")          # jitted, input donated (step 5)
    y = sort(jnp.array(x))
    ok = bool(jnp.all(y[1:] >= y[:-1]))
    print(f"sort {name:22s} sorted={ok}")

# --- placement primitives: data carries its homing ---
homed = conventional.put(jnp.arange(1 << 16, dtype=jnp.float32))  # born hashed
print(f"homed: shape={homed.shape} homing={homed.homing.value} "
      f"logical[:3]={homed.logical()[:3].tolist()}")

# --- Fig-1 micro-benchmark semantics ---
for name, loc in [("localised", localised), ("hash-for-home", conventional)]:
    bench = loc.workload("microbench", reps=16)
    out = bench(jnp.linspace(0, 1, 1 << 16))
    print(f"microbench {name:14s} checksum={float(out.sum()):.2f}")
print("ok")
