"""Quickstart: the paper's technique in 30 lines.

Run:  PYTHONPATH=src python examples/quickstart.py
(For a real multi-worker demo: XLA_FLAGS=--xla_force_host_platform_device_count=8)
"""
import jax
import jax.numpy as jnp

from repro.core import (Homing, LocalisationPolicy, distributed_merge_sort,
                        repetitive_copy)

mesh = (jax.make_mesh((len(jax.devices()),), ("data",))
        if len(jax.devices()) > 1 else None)

# --- the paper's Table-1 extremes ---
localised = LocalisationPolicy(localised=True, static_mapping=True,
                               homing=Homing.LOCAL_CHUNKED)      # Case 8
conventional = LocalisationPolicy(localised=False, static_mapping=True,
                                  homing=Homing.HASH_INTERLEAVED)  # Case 3

x = jax.random.randint(jax.random.key(0), (1 << 18,), 0, 1 << 30, jnp.int32)
for name, pol in [("localised(case8)", localised),
                  ("conventional(case3)", conventional)]:
    y = distributed_merge_sort(x, mesh=mesh, policy=pol)
    ok = bool(jnp.all(y[1:] >= y[:-1]))
    print(f"sort {name:22s} sorted={ok}")

# --- Fig-1 micro-benchmark semantics ---
xf = jnp.linspace(0, 1, 1 << 16)
for name, pol in [("localised", localised), ("hash-for-home", conventional)]:
    out = repetitive_copy(xf, 16, mesh, pol)
    print(f"microbench {name:14s} checksum={float(out.sum()):.2f}")
print("ok")
