"""Serve a small LM with batched requests through the decode server.

Run:  PYTHONPATH=src python examples/serve_decode.py

The same request stream is served twice — once under the ``fifo``
arrival-order oracle, once under the ``homed`` scheduler that routes,
batches and evicts by each slot's cache home — and the decoded tokens are
asserted bit-identical (a fixed ``prompt_pad`` makes every row's numerics
independent of wave composition), so the two policies differ only in
waves, waits and cross-home relayout bytes.
"""
import numpy as np

import jax

from repro.configs import get_config, reduce_config
from repro.models.model import LM
from repro.runtime.server import DecodeServer, Request


def stream(cfg, n=10, sessions=3):
    rng = np.random.RandomState(0)
    return [Request(rid=rid,
                    prompt=rng.randint(0, cfg.vocab_size, rng.randint(2, 9))
                    .astype(np.int32),
                    max_new=int(rng.choice([4, 8])),
                    session=f"user{rng.randint(sessions)}",
                    t_arrive=float(rid // 4))
            for rid in range(n)]


def main():
    cfg = reduce_config(get_config("granite-3-2b"), layers=4)
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    outs = {}
    for policy in ("fifo", "homed"):
        srv = DecodeServer(cfg, params, batch_slots=4, max_len=96,
                           scheduler=policy, prompt_pad=8)
        for r in stream(cfg):
            srv.submit(r)
        served = srv.run()
        assert all(r.done for r in served)
        outs[policy] = {r.rid: r.out for r in served}
        print(f"--- policy={policy}: {len(served)} requests, "
              f"{srv.scheduler.stats.waves} waves of {srv.B} slots ---")
        for r in sorted(served, key=lambda r: r.rid):
            print(f"req {r.rid} (session {r.session}, home {r.home}): "
                  f"-> {r.out}")
        print(srv.scheduler.format_summary())
    assert outs["fifo"] == outs["homed"], "policies must decode identically"
    print("fifo and homed decoded bit-identical tokens")


if __name__ == "__main__":
    main()
