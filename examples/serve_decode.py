"""Serve a small LM with batched requests through the decode server.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
import numpy as np

import jax

from repro.configs import get_config, reduce_config
from repro.models.model import LM
from repro.runtime.server import DecodeServer, Request


def main():
    cfg = reduce_config(get_config("granite-3-2b"), layers=4)
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    srv = DecodeServer(cfg, params, batch_slots=4, max_len=96)
    rng = np.random.RandomState(0)
    for rid in range(10):
        plen = rng.randint(2, 9)
        srv.submit(Request(rid=rid,
                           prompt=rng.randint(0, cfg.vocab_size, plen)
                           .astype(np.int32),
                           max_new=8))
    served = srv.run()
    for r in served:
        print(f"req {r.rid}: prompt_len={len(r.prompt)} -> tokens {r.out}")
    assert all(r.done for r in served)
    print(f"served {len(served)} requests in "
          f"{-(-len(served) // srv.B)} waves of {srv.B} slots")


if __name__ == "__main__":
    main()
