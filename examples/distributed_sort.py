"""The paper's merge-sort experiment across all Table-1 cases, on both
execution backends:

  * ``constraint`` — the `with_sharding_constraint` hint tree (layout left
    to the XLA SPMD partitioner);
  * ``shard_map``  — the explicit engine: per-device ownership, the Pallas
    bitonic kernel as the local sort (interpret mode on CPU), and explicit
    ppermute / all_gather / all_to_all exchanges per `LocalisationPolicy`.

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/distributed_sort.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs.paper_sort import CASES
from repro.core import Homing, LocalisationPolicy
from repro.core.sort import BACKENDS, make_sort_fn
from repro.kernels import ops


def main():
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",)) if n_dev > 1 else None
    n = 1 << 18
    for backend in BACKENDS:
        # the engine's Pallas leaf sort only interprets on CPU — keep the
        # example snappy with the jnp leaf sort at full size
        local_sort = jnp.sort if backend == "shard_map" else None
        for num, c in sorted(CASES.items()):
            pol = LocalisationPolicy(localised=c.localised,
                                     static_mapping=c.static_mapping,
                                     homing=Homing(c.homing))
            fn = make_sort_fn(mesh, pol, num_workers=max(n_dev, 8),
                              local_sort=local_sort, backend=backend)
            x = jax.random.randint(jax.random.key(0), (n,), 0, 1 << 30,
                                   jnp.int32)
            t0 = time.perf_counter()
            y = jax.block_until_ready(fn(x))
            dt = time.perf_counter() - t0
            assert bool(jnp.all(y[1:] >= y[:-1]))
            print(f"{backend:10s} case {num} ({pol.name:22s}): "
                  f"{dt*1e3:8.1f} ms  sorted=True")

    # the engine end-to-end with its real local phase: the Pallas bitonic
    # kernel running inside each shard (VMEM-resident sort, Algorithm 2)
    x = jax.random.randint(jax.random.key(1), (1 << 12,), 0, 1 << 30,
                           dtype=jnp.int32)
    fn = make_sort_fn(mesh, LocalisationPolicy(), backend="shard_map")
    y = jax.block_until_ready(fn(x))
    assert bool(jnp.all(y[1:] >= y[:-1]))
    print("shard_map engine + pallas bitonic local sort: ok (interpret mode)")

    # the kernel standalone
    xs = jax.random.randint(jax.random.key(1), (8, 512), 0, 1 << 30,
                            dtype=jnp.int32)
    ys = ops.bitonic_sort(xs)
    assert bool(jnp.all(ys[:, 1:] >= ys[:, :-1]))
    print("pallas bitonic local sort: ok (interpret mode)")


if __name__ == "__main__":
    main()
