"""The paper's merge-sort experiment across all Table-1 cases, with the
Pallas bitonic kernel as the local sort (interpret mode on CPU).

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/distributed_sort.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs.paper_sort import CASES
from repro.core import Homing, LocalisationPolicy
from repro.core.sort import make_sort_fn
from repro.kernels import ops


def main():
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",)) if n_dev > 1 else None
    n = 1 << 18
    for num, c in sorted(CASES.items()):
        pol = LocalisationPolicy(localised=c.localised,
                                 static_mapping=c.static_mapping,
                                 homing=Homing(c.homing))
        fn = make_sort_fn(mesh, pol, num_workers=max(n_dev, 8))
        x = jax.random.randint(jax.random.key(0), (n,), 0, 1 << 30, jnp.int32)
        t0 = time.perf_counter()
        y = jax.block_until_ready(fn(x))
        dt = time.perf_counter() - t0
        assert bool(jnp.all(y[1:] >= y[:-1]))
        print(f"case {num} ({pol.name:22s}): {dt*1e3:8.1f} ms  sorted=True")

    # local phase on the Pallas bitonic kernel (VMEM-resident sort)
    xs = jax.random.randint(jax.random.key(1), (8, 512), 0, 1 << 30,
                            dtype=jnp.int32)
    ys = ops.bitonic_sort(xs)
    assert bool(jnp.all(ys[:, 1:] >= ys[:, :-1]))
    print("pallas bitonic local sort: ok (interpret mode)")


if __name__ == "__main__":
    main()
