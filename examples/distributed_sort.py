"""The paper's merge-sort experiment across all Table-1 cases, on both
execution backends:

  * ``constraint`` — the `with_sharding_constraint` hint tree (layout left
    to the XLA SPMD partitioner);
  * ``shard_map``  — the explicit engine: per-device ownership, the Pallas
    bitonic kernel as the local sort (interpret mode on CPU), and explicit
    ppermute / all_gather / all_to_all exchanges per `LocalisationPolicy`.

Every case is one `Locale` (same mesh + axis, different policy) and the
sort comes from ``locale.workload("sort", backend=...)``.

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/distributed_sort.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs.paper_sort import CASES
from repro.core import BACKENDS, Homing, Locale, LocalisationPolicy
from repro.kernels import ops


def main():
    n_dev = len(jax.devices())
    locale = Locale.auto()
    n = 1 << 18
    for backend in BACKENDS:
        # the engine's Pallas leaf sort only interprets on CPU — keep the
        # example snappy with the jnp leaf sort at full size
        local_sort = jnp.sort if backend == "shard_map" else None
        for num, c in sorted(CASES.items()):
            pol = LocalisationPolicy(localised=c.localised,
                                     static_mapping=c.static_mapping,
                                     homing=Homing(c.homing))
            fn = locale.with_policy(pol).workload(
                "sort", backend=backend, local_sort=local_sort,
                num_workers=max(n_dev, 8))
            x = jax.random.randint(jax.random.key(0), (n,), 0, 1 << 30,
                                   jnp.int32)
            t0 = time.perf_counter()
            y = jax.block_until_ready(fn(x))
            dt = time.perf_counter() - t0
            assert bool(jnp.all(y[1:] >= y[:-1]))
            print(f"{backend:10s} case {num} ({pol.name:22s}): "
                  f"{dt*1e3:8.1f} ms  sorted=True")

    # the engine end-to-end with its real local phase: ONE fused pallas_call
    # per chunk (leaf sorts + the whole local merge tree in VMEM) and
    # merge-path merge-splits that compute only the kept half (Algorithm 2
    # for the entire local phase — local_phase="pallas", the default)
    x = jax.random.randint(jax.random.key(1), (1 << 12,), 0, 1 << 30,
                           dtype=jnp.int32)
    fn = locale.workload("engine", local_phase="pallas")
    y = jax.block_until_ready(fn(x))
    assert bool(jnp.all(y[1:] >= y[:-1]))
    print("shard_map engine + fused pallas local phase: ok (interpret mode)")

    # two distance classes: an emulated (pod, data, model) mesh, the deep
    # merge-split levels confined to intra-pod ppermutes and ONE all_gather
    # over the pod axis per top level (see README "Hierarchy")
    if n_dev >= 2 and n_dev % 2 == 0:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(n_data=n_dev // 2, n_model=1, n_pods=2)
        hier = Locale(mesh=mesh, axis=("pod", "data"),
                      policy=LocalisationPolicy.hierarchical())
        fn = hier.workload("sort", backend="shard_map", local_sort=jnp.sort)
        x = jax.random.randint(jax.random.key(2), (1 << 14,), 0, 1 << 30,
                               dtype=jnp.int32)
        y = jax.block_until_ready(fn(x))
        assert bool(jnp.all(y[1:] >= y[:-1]))
        print(f"hierarchical engine on 2x{n_dev // 2} emulated pods: ok")

    # the kernels standalone: leaf-only bitonic, the fused local phase
    # (non-power-of-two rows pad in VMEM scratch, never in HBM), and the
    # kept-half-only merge split
    xs = jax.random.randint(jax.random.key(1), (8, 512), 0, 1 << 30,
                            dtype=jnp.int32)
    ys = ops.bitonic_sort(xs)
    assert bool(jnp.all(ys[:, 1:] >= ys[:, :-1]))
    zs = ops.local_sort(jax.random.randint(jax.random.key(3), (4, 384),
                                           0, 1 << 30, dtype=jnp.int32))
    assert bool(jnp.all(zs[:, 1:] >= zs[:, :-1]))
    lo = ops.merge_split(ys[:4], ys[4:], jnp.ones((4,), bool))
    assert bool(jnp.all(lo[:, 1:] >= lo[:, :-1]))
    print("pallas kernels (bitonic / fused local_sort / merge_split): ok")


if __name__ == "__main__":
    main()
