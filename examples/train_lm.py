"""End-to-end driver: train a ~100M-param qwen3-family LM for a few hundred
steps with the full production stack (data pipeline, AdamW+cosine, atomic
checkpoints, loss-spike guard, resume).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--arch qwen3-0.6b]
CPU note: uses a width-reduced config by default so a few hundred steps fit
in minutes; pass --full for the real config (TPU-scale).
"""
import argparse

from repro.configs import get_config, reduce_config
from repro.runtime.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if not args.full:
        # ~100M-param qwen3-family config sized for the CPU harness
        cfg = cfg.replace(num_layers=12, d_model=640, num_heads=10,
                          num_kv_heads=2, head_dim=64, d_ff=2560,
                          vocab_size=2048,
                          dtype="float32", param_dtype="float32",
                          parallel=reduce_config(cfg).parallel)
    n = cfg.param_counts()["total"]
    print(f"arch={cfg.name} params={n/1e6:.1f}M", flush=True)
    t = TrainerConfig(steps=args.steps, global_batch=4, seq_len=64,
                      ckpt_dir=args.ckpt, ckpt_every=50, log_every=10,
                      lr=2e-3, warmup=20,
                      metrics_path="results/train_lm_metrics.json")
    res = Trainer(cfg, t).run()
    print(f"done: step={res['final_step']} loss={res['final_loss']:.4f}")


if __name__ == "__main__":
    main()
