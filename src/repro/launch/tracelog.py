"""Trace tooling: validate a JSONL trace's counter identities, or export
it as Chrome trace-event JSON for ``chrome://tracing`` / Perfetto.

    PYTHONPATH=src python -m repro.launch.tracelog TRACE.jsonl --validate
    PYTHONPATH=src python -m repro.launch.tracelog TRACE.jsonl \
        --chrome trace.json

``--validate`` replays the trace through `repro.obs.reconcile` and proves
the identities (charged relayout bytes == scheduler stats == summary;
pool acquires − releases − invalidations == live refs; every off-home
decode has a matching charge; the engine's stamped per-level bytes == a
fresh `exchange_schedule`).  Exit code 0 iff every identity holds — the
CI gate runs this against a traced smoke serve.

``--chrome`` converts the records to the Chrome trace-event format; load
the output at https://ui.perfetto.dev or ``chrome://tracing``.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs.tracelog import read_jsonl, to_chrome


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("trace", help="JSONL trace (from --trace PATH)")
    ap.add_argument("--validate", action="store_true",
                    help="replay the trace and prove the counter "
                    "identities; nonzero exit on any failure")
    ap.add_argument("--chrome", default=None, metavar="OUT",
                    help="write Chrome trace-event JSON here")
    ap.add_argument("--summary", action="store_true",
                    help="print record-kind counts and the traced "
                    "sched.summary dicts")
    args = ap.parse_args(argv)
    records = read_jsonl(args.trace)

    if args.summary or not (args.validate or args.chrome):
        kinds = {}
        for r in records:
            kinds[r.get("name", "?")] = kinds.get(r.get("name", "?"), 0) + 1
        for name in sorted(kinds):
            print(f"{kinds[name]:>7}  {name}")
        for r in records:
            if r.get("name") == "sched.summary":
                print(json.dumps(r["args"]))

    if args.chrome:
        with open(args.chrome, "w") as f:
            json.dump(to_chrome(records), f)
        print(f"# chrome trace: {args.chrome} "
              f"({len(records)} records)")

    if args.validate:
        # local import: reconcile pulls in the engine's analytic model
        from repro.obs.reconcile import ReconcileError, reconcile
        try:
            report = reconcile(records)
        except ReconcileError as e:
            print(f"FAIL {args.trace}: {e}", file=sys.stderr)
            return 1
        print(f"OK {args.trace}: {report['segments']} segment(s), "
              f"served={report['served']}, "
              f"relayout={report['relayout_bytes']}B, "
              f"engine_sorts={report['engine_sorts']}; "
              f"checks: {', '.join(report['checks'])}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
