"""homecheck CLI: statically verify workloads against their home contract.

    PYTHONPATH=src python -m repro.launch.homecheck \
        --workload sort|engine|microbench|serve|all \
        [--pods PxD[xM]] [--policy flat|hash|nonloc|nonloc-hash|hier|hier-hash] \
        [--backend shard_map|constraint] [--logn N] [--num-workers W] \
        [--rules all|R1 R5 R6 ...] [--suppress R4 ...] [--json] [--verbose]

Lowers the selected workload(s) over the requested (emulated) mesh and
runs rules R1-R11 (see `repro.analysis`) on the partitioned HLO + jaxpr +
exchange network — nothing executes.  ``--rules`` selects a subset
(default all): R1/R2 collective budget + home leaks, R3 VMEM, R4
donation, R5 pallas write-race/coverage, R6 sorting-network
certification, R7 index-arithmetic/sentinel lint, R8 dead grid lanes,
R9 scheduler-invariant certification, R10 HBM live-range vs the
per-device ceiling (``--hbm-ceiling`` overrides), R11 collectives under
data-dependent control flow.
When R6 is active the sweep also prints the repo-wide certificate: every
supported policy 0-1-certified over every mesh shape up to 16 devices.
When R9 is active the sweep prints the scheduler certificate: invariants
I1-I8 proved by exhaustive interleaving search over the full small-config
lattice (per-target reports run the fast corner; the certificate here is
the full one).
``--pods`` sets ``XLA_FLAGS`` itself, so the command is self-sufficient
on a laptop.  Exit status 1 on any ERROR-severity finding (and 2 on a
driver failure), so `runtime.ft.Supervisor`/CI can supervise it
uniformly.
"""
from __future__ import annotations

import argparse
import os
import sys


def parse_pods(spec: str):
    """``PxD`` or ``PxDxM`` -> (n_pods, n_data, n_model)."""
    parts = [int(p) for p in spec.lower().split("x")]
    if len(parts) == 2:
        parts.append(1)
    if len(parts) != 3 or any(p < 1 for p in parts):
        raise argparse.ArgumentTypeError(
            f"--pods wants PxD or PxDxM with positive ints, got {spec!r}")
    return tuple(parts)


POLICIES = ("auto", "flat", "hash", "nonloc", "nonloc-hash",
            "hier", "hier-hash", "all")


def make_policy(name: str, n_pods: int):
    """Resolve a --policy name to a LocalisationPolicy (lazy jax import)."""
    from repro.core.homing import Homing
    from repro.core.localisation import LocalisationPolicy
    if name == "auto":
        name = "hier" if n_pods > 1 else "flat"
    return {
        "flat": LocalisationPolicy(),
        "hash": LocalisationPolicy(homing=Homing.HASH_INTERLEAVED),
        "nonloc": LocalisationPolicy(localised=False),
        "nonloc-hash": LocalisationPolicy(
            localised=False, homing=Homing.HASH_INTERLEAVED),
        "hier": LocalisationPolicy.hierarchical(),
        "hier-hash": LocalisationPolicy.hierarchical(inner="hash"),
    }[name]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="static locality analyzer (homecheck)")
    ap.add_argument("--workload", default="sort",
                    choices=("sort", "engine", "microbench", "serve", "all"))
    ap.add_argument("--pods", type=parse_pods, default=None, metavar="PxD[xM]",
                    help="emulated (pod, data, model) mesh; sets XLA_FLAGS")
    ap.add_argument("--policy", choices=POLICIES, default="auto",
                    help="localisation policy (auto = hier on a pod mesh; "
                         "all = every policy the mesh supports)")
    ap.add_argument("--backend", choices=("shard_map", "constraint"),
                    default="shard_map",
                    help="sort backend (R1 needs the shard_map byte model)")
    ap.add_argument("--logn", type=int, default=12,
                    help="~log2 of the representative input length")
    ap.add_argument("--num-workers", type=int, default=None)
    ap.add_argument("--reps", type=int, default=4, help="microbench passes")
    ap.add_argument("--arch", default="qwen3-0.6b", help="serve config")
    ap.add_argument("--rules", nargs="*", default=None, metavar="RULE",
                    help="rules to run (R1..R11 or 'all'; default all); "
                         "with R6/R9 active the repo-wide mesh and "
                         "scheduler certificates are printed too")
    ap.add_argument("--hbm-ceiling", type=int, default=None,
                    help="R10 per-device HBM ceiling in bytes (default "
                         "repro.kernels.HBM_BYTES_PER_DEVICE)")
    ap.add_argument("--suppress", nargs="*", default=(), metavar="RULE",
                    help="rule ids to drop from the report (e.g. R4)")
    ap.add_argument("--json", action="store_true", dest="as_json")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)

    # the mesh is emulated out of host devices: the flag must be set before
    # jax (transitively, any repro module) first touches the backend.
    if args.pods is not None:
        n_dev = args.pods[0] * args.pods[1] * args.pods[2]
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={n_dev}").strip()

    import jax

    from repro.analysis import (certify_supported_meshes, check_decode,
                                check_workload, normalize_rules, summarize)
    from repro.core.api import Locale
    from repro.launch.mesh import make_host_mesh

    try:
        rules = normalize_rules(args.rules)
    except ValueError as e:
        ap.error(str(e))

    if args.pods is not None:
        p, d, m = args.pods
        mesh = make_host_mesh(n_pods=p, n_data=d, n_model=m)
        sort_axis = ("pod", "data") if p > 1 else "data"
        n_pods = p
    else:
        n_dev = len(jax.devices())
        mesh = make_host_mesh(n_data=n_dev, n_model=1) if n_dev > 1 else None
        sort_axis = "data"
        n_pods = 1
    def pol_names(workload: str):
        """--policy all: every policy the mesh supports for this workload."""
        if args.policy != "all":
            return (args.policy,)
        if workload == "microbench":            # Fig-1 bench: loc vs nonloc
            return ("flat", "nonloc")
        return (("hier", "hier-hash") if n_pods > 1
                else ("flat", "hash", "nonloc", "nonloc-hash"))

    names = (("sort", "microbench", "serve") if args.workload == "all"
             else (args.workload,))
    reports = []
    for name in names:
        if name == "serve":
            reports.append(check_decode(mesh, cfg_name=args.arch,
                                        hbm_ceiling=args.hbm_ceiling,
                                        rules=rules,
                                        suppress=args.suppress))
            continue
        for pname in pol_names(name):
            locale = Locale(mesh=mesh, axis=sort_axis,
                            policy=make_policy(pname, n_pods))
            reports.append(check_workload(
                locale, name, backend=args.backend,
                num_workers=args.num_workers, logn=args.logn,
                reps=args.reps, hbm_ceiling=args.hbm_ceiling,
                rules=rules, suppress=args.suppress))

    for rep in reports:
        print(rep.to_json() if args.as_json
              else rep.format(verbose=args.verbose))

    cert_errors = 0
    if "R9" in rules:
        from repro.analysis import DEFAULT_LATTICE, certify_lattice
        cert = certify_lattice(DEFAULT_LATTICE)
        bad = {n: rec for n, rec in cert.items()
               if rec["witness"] is not None}
        total_states = sum(rec["states"] for rec in cert.values())
        if bad:
            cert_errors += len(bad)
            for n, rec in bad.items():
                print(f"R9 certificate FAILED [{n}]: "
                      f"{rec['witness'].format()}")
        else:
            configs = ", ".join(f"{n}({rec['states']})"
                                for n, rec in cert.items())
            print(f"R9 certificate [scheduler]: I1-I8 hold over "
                  f"{len(cert)} lattice config(s), {total_states} "
                  f"canonical states explored exhaustively ({configs})")
    if "R6" in rules:
        cert = certify_supported_meshes()
        for pname, rec in sorted(cert.items()):
            meshes = ", ".join("x".join(map(str, s))
                               for s in rec["certified"])
            line = (f"R6 certificate [{pname}]: "
                    f"{len(rec['certified'])} mesh(es) 0-1 certified"
                    f" ({meshes})")
            if rec["failed"]:
                cert_errors += len(rec["failed"])
                line += f"; FAILED: {rec['failed']}"
            print(line)

    dirty, errors = summarize(reports)
    errors += cert_errors
    total = sum(len(r.findings) for r in reports)
    print(f"homecheck: {len(reports)} target(s), {total} finding(s), "
          f"{errors} error(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
