"""Production training launcher: mesh + plan + fault-tolerant Trainer.

On a real pod:
    python -m repro.launch.train --arch glm4-9b --production [--multipod]
On this host (reduced config, real end-to-end loop):
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --steps 30

Under `--supervised` the loop runs beneath the heartbeat Supervisor:
crashes/hangs relaunch from the latest atomic checkpoint.
"""
from __future__ import annotations

import argparse
import sys

import jax

from repro.configs import get_config, reduce_config
from repro.launch.mesh import make_production_mesh
from repro.configs.base import SHAPES
from repro.runtime.trainer import Trainer, TrainerConfig
from repro.sharding.partition import NULL_PLAN, make_plan


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--ckpt", default="/tmp/repro_launch_train")
    ap.add_argument("--production", action="store_true",
                    help="full config on the production mesh (TPU pods)")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--supervised", action="store_true")
    args = ap.parse_args()

    if args.supervised:
        from repro.runtime.ft import Supervisor
        cmd = [sys.executable, "-m", "repro.launch.train"] + [
            a for a in sys.argv[1:] if a != "--supervised"]
        out = Supervisor(cmd=cmd, max_restarts=3).run()
        print("\n".join(out["stdout"][-5:]))
        sys.exit(0 if out["ok"] else 1)

    if args.production:
        cfg = get_config(args.arch)
        mesh = make_production_mesh(multi_pod=args.multipod)
        plan = make_plan(mesh, cfg, SHAPES["train_4k"])
    else:
        cfg = reduce_config(get_config(args.arch))
        mesh, plan = None, NULL_PLAN
    t = TrainerConfig(steps=args.steps, global_batch=args.global_batch,
                      seq_len=args.seq_len, ckpt_dir=args.ckpt,
                      ckpt_every=max(args.steps // 5, 1), log_every=10)
    res = Trainer(cfg, t, plan=plan, mesh=mesh).run()
    print(f"done: step={res['final_step']} loss={res['final_loss']}")


if __name__ == "__main__":
    main()
