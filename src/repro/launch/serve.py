"""Serving launcher: batched decode server over a (restored) checkpoint.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
        [--ckpt DIR] [--requests 8] [--slots 4] \
        [--policy fifo|homed] [--pods PxD[xM]]

``--policy`` selects the serving scheduler (`repro.runtime.scheduler`):
``fifo`` is the arrival-order oracle, ``homed`` routes/batches/evicts by
each slot's cache home.  ``--pods PxD[xM]`` serves over an emulated-pod
mesh (run under ``XLA_FLAGS=--xla_force_host_platform_device_count=N``)
so the scheduler's inter-pod vs intra-pod relayout split is visible on a
laptop.  The per-home admission summary prints at exit either way — the
launcher demonstrates the scheduler without reading code.

``--trace PATH`` streams a structured JSONL trace of the whole run
(scheduler decisions, charges, pool pins, per-wave decode spans) —
validate its counter identities with ``python -m repro.launch.tracelog
PATH --validate`` or export it for Perfetto with ``--chrome``.
"""
from __future__ import annotations

import argparse

import numpy as np

import jax

from repro.checkpoint import latest_step, restore
from repro.configs import get_config, reduce_config
from repro.configs.base import ShapeSpec
from repro.models.model import LM
from repro.obs import Tracer, set_tracer
from repro.obs import metrics as obs_metrics
from repro.runtime.server import DecodeServer, Request


def parse_pods(spec: str):
    """``PxD`` or ``PxDxM`` -> (n_pods, n_data, n_model)."""
    parts = [int(p) for p in spec.lower().split("x")]
    if len(parts) == 2:
        parts.append(1)
    if len(parts) != 3 or any(p < 1 for p in parts):
        raise argparse.ArgumentTypeError(
            f"--pods wants PxD or PxDxM with positive ints, got {spec!r}")
    return tuple(parts)


def build_plan(pods, slots: int, max_len: int, cfg):
    """The serving MeshPlan: flat data mesh, or the emulated-pod mesh."""
    from repro.launch.mesh import make_host_mesh
    from repro.sharding.partition import NULL_PLAN, make_plan
    n_dev = len(jax.devices())
    if pods is None:
        if n_dev == 1:
            return NULL_PLAN
        mesh = make_host_mesh(n_data=n_dev, n_model=1)
    else:
        p, d, m = pods
        mesh = make_host_mesh(n_pods=p, n_data=d, n_model=m)
    return make_plan(mesh, cfg, ShapeSpec("serve", max_len, slots, "decode"))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--policy", choices=("fifo", "homed"), default="fifo",
                    help="serving scheduler: arrival-order oracle vs "
                    "home-aware routing/batching/eviction")
    ap.add_argument("--pods", type=parse_pods, default=None, metavar="PxD[xM]",
                    help="serve over an emulated (pod, data, model) mesh")
    ap.add_argument("--sessions", type=int, default=4,
                    help="distinct affinity keys in the synthetic stream")
    ap.add_argument("--prompt-pad", type=int, default=16,
                    help="fixed prefill pad bucket (wave-composition-"
                    "independent numerics); 0 = per-wave max")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="stream a structured JSONL trace here (validate "
                    "with `python -m repro.launch.tracelog PATH --validate`)")
    ap.add_argument("--json", action="store_true",
                    help="also print the summary as one JSON line (same "
                    "dict the human report and bench rows render)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: 8 requests, 4 slots, max-new 4 "
                    "(the traced smoke the gate validates)")
    args = ap.parse_args(argv)
    if args.smoke:
        args.requests, args.slots, args.max_new = 8, 4, 4

    cfg = reduce_config(get_config(args.arch), layers=4)
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    if args.ckpt and latest_step(args.ckpt) is not None:
        params = restore(args.ckpt, latest_step(args.ckpt),
                         {"params": params})["params"]
    plan = build_plan(args.pods, args.slots, 96, cfg)
    tracer = None
    if args.trace:
        tracer = Tracer(args.trace, tool="launch.serve", arch=args.arch,
                        policy=args.policy, slots=args.slots,
                        pods=args.pods, requests=args.requests)
        set_tracer(tracer)     # engine-level spans join the same stream
    srv = DecodeServer(cfg, params, batch_slots=args.slots, max_len=96,
                       plan=plan, scheduler=args.policy,
                       prompt_pad=args.prompt_pad or None, tracer=tracer)
    rng = np.random.RandomState(0)
    for rid in range(args.requests):
        plen = rng.randint(2, 9)
        srv.submit(Request(
            rid=rid,
            prompt=rng.randint(0, cfg.vocab_size, plen).astype(np.int32),
            max_new=int(rng.choice([args.max_new // 2 or 1, args.max_new])),
            session=f"s{rng.randint(args.sessions)}",
            t_arrive=float(rid // max(1, args.slots))))
    for r in sorted(srv.run(), key=lambda r: r.rid):
        print(f"req {r.rid} (session {r.session}, home {r.home}, "
              f"wait {r.wait:.0f}): -> {r.out}")
    # one code path: the trace's sched.summary event, the human report
    # and the optional JSON line all render the same canonical dict
    summary = srv.scheduler.emit_summary()
    print(obs_metrics.format_summary(summary))
    if args.json:
        import json
        print(json.dumps(summary))
    if tracer is not None:
        tracer.close()
        set_tracer(None)
        print(f"# trace: {args.trace} ({len(tracer.records())} records)")


if __name__ == "__main__":
    main()
