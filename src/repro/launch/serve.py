"""Serving launcher: batched decode server over a (restored) checkpoint.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
        [--ckpt DIR] [--requests 8] [--slots 4]
"""
from __future__ import annotations

import argparse

import numpy as np

import jax

from repro.checkpoint import latest_step, restore
from repro.configs import get_config, reduce_config
from repro.models.model import LM
from repro.runtime.server import DecodeServer, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = reduce_config(get_config(args.arch), layers=4)
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    if args.ckpt and latest_step(args.ckpt) is not None:
        params = restore(args.ckpt, latest_step(args.ckpt),
                         {"params": params})["params"]
    srv = DecodeServer(cfg, params, batch_slots=args.slots, max_len=96)
    rng = np.random.RandomState(0)
    for rid in range(args.requests):
        srv.submit(Request(rid=rid,
                           prompt=rng.randint(0, cfg.vocab_size,
                                              rng.randint(2, 9)).astype(np.int32),
                           max_new=args.max_new))
    for r in srv.run():
        print(f"req {r.rid}: -> {r.out}")


if __name__ == "__main__":
    main()
