"""Dry-run sweep driver: every (arch x shape x mesh) cell in a subprocess.

Each cell runs in its own process (the 512-device placeholder platform and
XLA compile arenas die with it); existing JSONs are skipped so the sweep is
restartable — the same discipline the trainer applies to checkpoints.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

ARCH_ORDER = ["qwen3-0.6b", "granite-3-2b", "musicgen-medium", "mamba2-2.7b",
              "glm4-9b", "deepseek-moe-16b", "mixtral-8x7b", "granite-20b",
              "llama-3.2-vision-90b", "jamba-1.5-large-398b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def cell_path(outdir, arch, shape, mp):
    return os.path.join(outdir, f"{arch}_{shape}_{'mp' if mp else 'sp'}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="results/dryrun")
    ap.add_argument("--timeout", type=int, default=3600)
    ap.add_argument("--archs", default=",".join(ARCH_ORDER))
    ap.add_argument("--shapes", default=",".join(SHAPE_ORDER))
    ap.add_argument("--meshes", default="sp,mp")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)
    cells = [(a, s, mp) for mp in [m == "mp" for m in args.meshes.split(",")]
             for a in args.archs.split(",") for s in args.shapes.split(",")]
    t00 = time.time()
    for i, (arch, shape, mp) in enumerate(cells):
        out = cell_path(args.outdir, arch, shape, mp)
        if os.path.exists(out) and not args.force:
            try:
                st = json.load(open(out)).get("status")
            except Exception:
                st = "corrupt"
            if st in ("ok", "skipped"):
                print(f"[{i+1}/{len(cells)}] SKIP (exists, {st}) {out}",
                      flush=True)
                continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--out", out]
        if mp:
            cmd.append("--multipod")
        t0 = time.time()
        print(f"[{i+1}/{len(cells)}] RUN {arch} {shape} "
              f"{'mp' if mp else 'sp'} ...", flush=True)
        try:
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout,
                               env={**os.environ, "JAX_PLATFORMS": "cpu"})
            status = "ok" if r.returncode == 0 else "FAIL"
            tail = (r.stdout + r.stderr).strip().splitlines()[-1:] \
                if status == "FAIL" else []
        except subprocess.TimeoutExpired:
            status, tail = "TIMEOUT", []
            with open(out, "w") as f:
                json.dump({"arch": arch, "shape": shape, "multi_pod": mp,
                           "status": "timeout"}, f)
        print(f"    -> {status} in {time.time()-t0:.0f}s "
              f"(total {time.time()-t00:.0f}s) {' '.join(tail)[:300]}",
              flush=True)


if __name__ == "__main__":
    main()
