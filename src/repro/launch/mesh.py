"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state. Device order is *static* — the paper's static
thread->core mapping: chunk i of the data always lives on the same chip.

Two hierarchy levels: the ``data``/``model`` axes live on the fast
intra-pod interconnect (ICI); the ``pod`` axis is the slow cross-pod link
(DCN).  `make_host_mesh(n_pods=...)` builds the *emulated-pod* form of the
same (pod, data, model) topology out of local (or placeholder host)
devices, so tests and benchmarks exercise the hierarchical engine without
real multi-host hardware — e.g. ``XLA_FLAGS=--xla_force_host_platform_
device_count=8`` plus ``make_host_mesh(n_pods=2, n_data=2, n_model=2)``.
"""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_data: int | None = None, n_model: int = 1,
                   n_pods: int | None = None):
    """Small mesh over whatever local devices exist (tests/benchmarks).

    With ``n_pods`` the mesh gains a leading ``pod`` axis — the emulated-pod
    path for the hierarchical engine.  The shape is validated up front:
    every requested factor must divide the device count and the full shape
    must use *exactly* the available devices, otherwise `jax.make_mesh`
    either crashes opaquely (non-divisor) or silently builds a mesh over a
    device subset (undersized shape).
    """
    n = len(jax.devices())
    outer = (n_pods,) if n_pods is not None else ()
    for name, size in (("n_pods", n_pods), ("n_model", n_model),
                       ("n_data", n_data)):
        if size is not None and (not isinstance(size, int) or size < 1):
            raise ValueError(f"{name}={size!r} must be a positive int")
    fixed = n_model * (n_pods or 1)
    if n % fixed != 0:
        raise ValueError(
            f"cannot mesh {n} host device(s): n_model={n_model}"
            + (f" x n_pods={n_pods}" if n_pods is not None else "")
            + f" = {fixed} does not divide the device count {n}")
    n_data = n_data or (n // fixed)
    shape = outer + (n_data, n_model)
    axes = (("pod",) if n_pods is not None else ()) + ("data", "model")
    want = math.prod(shape)
    if want != n:
        raise ValueError(
            f"requested mesh shape {dict(zip(axes, shape))} needs {want} "
            f"device(s) but this host has {n} — the shape must use exactly "
            f"the available devices")
    return jax.make_mesh(shape, axes)
