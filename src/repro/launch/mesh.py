"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state. Device order is *static* — the paper's static
thread->core mapping: chunk i of the data always lives on the same chip.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_data: int | None = None, n_model: int = 1):
    """Small mesh over whatever local devices exist (tests/benchmarks)."""
    n = len(jax.devices())
    n_data = n_data or (n // n_model)
    return jax.make_mesh((n_data, n_model), ("data", "model"))
