import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
# ^ MUST precede any other import: jax locks the device count on first init.

import argparse
import json
import re
import sys
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_NAMES, SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.models.model import LM
from repro.models.steps import (init_opt_state, make_decode_step,
                                make_prefill_step, make_train_step)
from repro.optim import AdamW
from repro.sharding.partition import (batch_specs, cache_specs, full_opt_specs,
                                      make_plan, param_specs)

# ---------------------------------------------------------------------------
# hardware model (TPU v5e target)
# ---------------------------------------------------------------------------
PEAK_FLOPS = 197e12       # bf16 FLOP/s per chip
HBM_BW = 819e9            # B/s per chip
LINK_BW = 50e9            # B/s per ICI link


from repro.launch.hlo_cost import analyze as hlo_analyze


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------
def input_specs(cfg, shape):
    """ShapeDtypeStructs for every model input of this (arch, shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        batch = {"targets": sds((B, S), jnp.int32)}
        if cfg.embed_input:
            batch["tokens"] = sds((B, S), jnp.int32)
        else:
            batch["embeds"] = sds((B, S, cfg.d_model), dt)
        if cfg.family == "vlm":
            batch["image_embeds"] = sds((B, cfg.num_image_tokens, cfg.d_model), dt)
        return batch
    if shape.kind == "prefill":
        batch = {}
        if cfg.embed_input:
            batch["tokens"] = sds((B, S), jnp.int32)
        else:
            batch["embeds"] = sds((B, S, cfg.d_model), dt)
        if cfg.family == "vlm":
            batch["image_embeds"] = sds((B, cfg.num_image_tokens, cfg.d_model), dt)
        return batch
    # decode: one new token, KV cache of seq_len
    batch = {}
    if cfg.embed_input:
        batch["tokens"] = sds((B, 1), jnp.int32)
    else:
        batch["embeds"] = sds((B, 1, cfg.d_model), dt)
    return batch


def _named(tree_specs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda s: isinstance(s, P))


# ---------------------------------------------------------------------------
# one cell: lower + compile + analyse
# ---------------------------------------------------------------------------
def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save_hlo: str | None = None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.subquadratic:
        return {"arch": arch, "shape": shape_name,
                "multi_pod": multi_pod, "status": "skipped",
                "reason": "pure full-attention arch; long_500k needs "
                          "sub-quadratic attention (DESIGN.md §4)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = make_plan(mesh, cfg, shape)
    model = LM(cfg)
    params_struct = model.param_struct()
    pspecs = param_specs(params_struct, plan, cfg)
    bstruct = input_specs(cfg, shape)
    bspecs = batch_specs(bstruct, plan)
    t0 = time.time()

    if shape.kind == "train":
        opt = AdamW(lr=3e-4)
        opt_struct = jax.eval_shape(partial(init_opt_state, cfg, opt),
                                    params_struct)
        ospecs = full_opt_specs(opt_struct, params_struct, plan, cfg)
        step = make_train_step(model, cfg, plan, opt)
        jitted = jax.jit(step,
                         in_shardings=(_named(pspecs, mesh),
                                       _named(ospecs, mesh),
                                       _named(bspecs, mesh)),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(params_struct, opt_struct, bstruct)
    elif shape.kind == "prefill":
        step = make_prefill_step(model, cfg, plan)
        jitted = jax.jit(step, in_shardings=(_named(pspecs, mesh),
                                             _named(bspecs, mesh)))
        lowered = jitted.lower(params_struct, bstruct)
    else:  # decode
        cache_struct = model.cache_struct(shape.global_batch, shape.seq_len)
        cspecs = cache_specs(cache_struct, plan, cfg)
        step = make_decode_step(model, cfg, plan)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        jitted = jax.jit(step,
                         in_shardings=(_named(pspecs, mesh),
                                       _named(cspecs, mesh),
                                       _named(bspecs, mesh),
                                       NamedSharding(mesh, P())),
                         donate_argnums=(1,))
        lowered = jitted.lower(params_struct, cache_struct, bstruct, pos)

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    n_chips = mesh.size
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):         # jax <= 0.4.x wraps in a list
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    parsed = hlo_analyze(hlo)                      # trip-count-aware (hlo_cost)
    flops_dev = float(parsed["flops"])
    bytes_dev = float(parsed["bytes"])
    mem = compiled.memory_analysis()
    mem_info = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            mem_info[attr] = int(v)
    counts = cfg.param_counts()
    tokens = shape.global_batch * (shape.seq_len if shape.kind == "train" else
                                   (shape.seq_len if shape.kind == "prefill" else 1))
    flops_mult = 6.0 if shape.kind == "train" else 2.0
    model_flops = flops_mult * counts["active"] * tokens

    compute_term = flops_dev / PEAK_FLOPS
    memory_term = bytes_dev / HBM_BW
    coll_term = parsed["collective_total"] / LINK_BW
    dominant = max([("compute", compute_term), ("memory", memory_term),
                    ("collective", coll_term)], key=lambda kv: kv[1])[0]
    per_dev_bytes = (mem_info.get("argument_size_in_bytes", 0)
                     - mem_info.get("alias_size_in_bytes", 0)
                     + mem_info.get("output_size_in_bytes", 0)
                     + mem_info.get("temp_size_in_bytes", 0))
    return {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "status": "ok", "n_chips": n_chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops_per_device": flops_dev, "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": parsed["collective_total"],
        "collective_detail": parsed["collective"],
        "collective_counts": parsed["collective_counts"],
        "top_bytes_ops": [f"{b:.3e} {k} {l}" for b, k, l in parsed["top_bytes"][:12]],
        "top_flops_ops": [f"{b:.3e} {k} {l}" for b, k, l in parsed["top_flops"][:12]],
        "xla_cost_analysis_raw": {"flops": float(cost.get("flops", 0.0)),
                                  "bytes": float(cost.get("bytes accessed", 0.0))},
        "while_trip_counts": parsed["while_trips"],
        "memory_analysis": mem_info,
        "per_device_hbm_bytes": per_dev_bytes,
        "model_flops_global": model_flops,
        "hlo_flops_global": flops_dev * n_chips,
        "useful_flops_ratio": (model_flops / (flops_dev * n_chips)
                               if flops_dev else 0.0),
        "roofline": {
            "compute_s": compute_term, "memory_s": memory_term,
            "collective_s": coll_term, "dominant": dominant,
        },
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--save-hlo", default=None)
    args = ap.parse_args()
    try:
        res = run_cell(args.arch, args.shape, args.multipod,
                       save_hlo=args.save_hlo)
    except Exception as e:  # noqa: BLE001 — sweep driver records failures
        res = {"arch": args.arch, "shape": args.shape,
               "multi_pod": args.multipod, "status": "error",
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    js = json.dumps(res, indent=1, default=float)
    if args.out:
        with open(args.out, "w") as f:
            f.write(js)
    print(js)
    if res["status"] == "ok":
        print(f"\n== memory analysis ==\n{res['memory_analysis']}")
        print(f"== cost analysis ==\nflops/dev={res['flops_per_device']:.3e} "
              f"bytes/dev={res['bytes_per_device']:.3e} "
              f"coll/dev={res['collective_bytes_per_device']:.3e}")
    sys.exit(0 if res["status"] in ("ok", "skipped") else 1)


if __name__ == "__main__":
    main()
