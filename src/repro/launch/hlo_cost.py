"""Trip-count-aware cost model over optimized (post-SPMD) HLO text.

XLA's built-in `compiled.cost_analysis()` counts each `while` body ONCE,
regardless of trip count (verified empirically: a 10-iteration scanned matmul
reports the FLOPs of a single matmul). Since this framework scans over
superblocks, microbatches and KV chunks, that undercounts FLOPs, bytes and —
critically — per-layer collectives by 1-3 orders of magnitude.

This module parses the partitioned HLO, builds the computation call graph,
recovers scan trip counts from the loop-condition constants, and accumulates:

  * dot FLOPs           (2 * prod(output dims) * prod(contracted dims))
  * HBM bytes           (operands + outputs of top-level ops; fusion
                         internals excluded — they never round-trip HBM;
                         dynamic-slice/update-slice counted at slice size,
                         matching in-place semantics for donated buffers)
  * collective wire bytes per device, by kind, with ring factors
                         (all-reduce 2x, others 1x)

All quantities are per-device (the module is the per-device SPMD program).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2,
                "u16": 2, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4,
                "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
                "f8e4m3fn": 1, "f8e5m2": 1, "token": 0}

COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
              "collective-permute")
COLL_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}

# Shapes may carry dynamic-dim markers (`s32[<=8]`) and layout suffixes whose
# tiling contains parens (`f32[8,16]{1,0:T(8,128)}`); tuple types may nest
# both (`(f32[8,16]{1,0:T(8,128)}, s32[8])`). The type pattern therefore
# allows one level of paren nesting and arbitrary (non-`]`) dim text.
_SHAPE_RE = re.compile(r"(\w+)\[([\d,<=]*)\]")
_TYPE_PAT = r"\((?:[^()]|\([^()]*\))*\)|\w+\[[^\]]*\]\S*"
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(" + _TYPE_PAT + r")\s*([\w\-]+)\(")
_PARAM_RE = re.compile(
    r"%?([\w.\-]+):\s*(\((?:[^()]|\([^()]*\))*\)"
    r"|\w+\[[^\]]*\](?:\{[^{}]*\})?)")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_COND_BODY_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_CDIMS = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_RG_BRACE_RE = re.compile(r"replica_groups=\{(\{[^{}]*\}(?:,\{[^{}]*\})*)\}")
_RG_IOTA_RE = re.compile(
    r"replica_groups=\[([\d,]+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_STP_RE = re.compile(r"source_target_pairs=\{(\{[^{}]*\}(?:,\{[^{}]*\})*)\}")


def _brace_groups(body: str) -> List[List[int]]:
    return [[int(x) for x in g.split(",") if x.strip()]
            for g in re.findall(r"\{([^{}]*)\}", body)]


def _iota_groups(reshape: List[int], iota: List[int],
                 perm: Optional[List[int]]) -> List[List[int]]:
    """Expand `[G,S]<=[d0,..]T(p..)` iota replica groups to explicit ids.

    Values 0..prod(iota)-1 are laid out row-major in `iota` dims, transposed
    by `perm`, then reshaped row-major to `reshape`; each trailing-dim row is
    one group.
    """
    perm = perm if perm is not None else list(range(len(iota)))
    strides = [1] * len(iota)
    for i in range(len(iota) - 2, -1, -1):
        strides[i] = strides[i + 1] * iota[i + 1]
    t_shape = [iota[p] for p in perm]
    vals: List[int] = []

    def rec(coord: List[int]) -> None:
        if len(coord) == len(t_shape):
            orig = [0] * len(iota)
            for j, p in enumerate(perm):
                orig[p] = coord[j]
            vals.append(sum(c * s for c, s in zip(orig, strides)))
            return
        for c in range(t_shape[len(coord)]):
            rec(coord + [c])

    rec([])
    gsize = reshape[-1]
    return [vals[i:i + gsize] for i in range(0, len(vals), gsize)]


def collective_groups(line: str) -> List[List[int]]:
    """Device groups of a collective op line, [] when unspecified (= all).

    collective-permute yields its (src, tgt) pairs; others yield the
    replica groups from either the explicit brace form or the iota form.
    """
    m = _STP_RE.search(line)
    if m:
        return _brace_groups(m.group(1))
    m = _RG_BRACE_RE.search(line)
    if m:
        return _brace_groups(m.group(1))
    m = _RG_IOTA_RE.search(line)
    if m:
        reshape = [int(x) for x in m.group(1).split(",")]
        iota = [int(x) for x in m.group(2).split(",")]
        perm = ([int(x) for x in m.group(3).split(",")]
                if m.group(3) else None)
        return _iota_groups(reshape, iota, perm)
    return []


def _wire_bytes(kind: str, b: float, group_size: int) -> float:
    """Per-device wire bytes for one execution of a collective.

    `b` is the payload (result) bytes. Verified against exchange_schedule:
    ppermute sends its whole buffer; all-gather moves (g-1)/g of the gathered
    result; all-to-all keeps 1/g resident; reduce-scatter reads g partials.
    """
    if kind == "collective-permute":
        return b
    g = group_size
    if g and g > 1:
        if kind == "all-gather":
            return b * (g - 1) / g
        if kind == "reduce-scatter":
            return b * (g - 1)
        if kind == "all-reduce":
            return 2.0 * b * (g - 1) / g
        return b * (g - 1) / g  # all-to-all
    return b * COLL_FACTOR[kind]


def _parse_shape(s: str) -> List[Tuple[str, List[int]]]:
    """'(f32[2,3], bf16[4])' or 'f32[2,3]{1,0}' -> list of (dtype, dims).

    Dynamic dims (`s32[<=8]`) are treated at their bound.
    """
    return [(d, [int(x.replace("<=", "")) for x in dims.split(",")
                 if x.replace("<=", "")])
            for d, dims in _SHAPE_RE.findall(s)]


def _shape_bytes(s: str) -> float:
    tot = 0.0
    for dt, dims in _parse_shape(s):
        n = 1
        for d in dims:
            n *= d
        tot += n * _DTYPE_BYTES.get(dt, 4)
    return tot


@dataclass
class Op:
    name: str
    opcode: str
    result: str
    line: str


@dataclass
class Computation:
    name: str
    params: Dict[str, str] = field(default_factory=dict)
    ops: List[Op] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)  # value name -> type str


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = None
    for line in text.splitlines():
        hdr = _COMP_HDR.match(line.strip()) if "{" in line and "->" in line else None
        if hdr and not line.strip().startswith("%constant"):
            cur = Computation(hdr.group(1))
            for pname, ptype in _PARAM_RE.findall(hdr.group(2)):
                cur.params[pname] = ptype
                cur.shapes[pname] = ptype
            comps[cur.name] = cur
            if line.strip().startswith("ENTRY"):
                entry = cur.name
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _DEF_RE.match(line)
        if m:
            name, result, opcode = m.group(1), m.group(2), m.group(3)
            cur.ops.append(Op(name, opcode, result, line))
            cur.shapes[name] = result
        elif "parameter(" in line:
            pm = re.match(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\S+)\s*parameter",
                          line)
            if pm:
                cur.shapes[pm.group(1)] = pm.group(2)
                cur.ops.append(Op(pm.group(1), "parameter", pm.group(2), line))
    comps["__entry__"] = comps[entry]
    return comps


def _dot_flops(op: Op, comp: "Computation") -> float:
    out = _parse_shape(op.result)
    if not out:
        return 0.0
    n_out = 1
    for d in out[0][1]:
        n_out *= d
    m = _LHS_CDIMS.search(op.line)
    cdims = [int(x) for x in m.group(1).split(",") if x] if m else []
    # lhs operand: inline shape if printed, else resolve via symbol table.
    # The operand list itself contains commas (inside shapes), so cut at the
    # closing paren of dot(...) rather than splitting on ",".
    rhs_part = op.line.split("dot(", 1)[1] if "dot(" in op.line else ""
    rhs_part = rhs_part.split(")", 1)[0]
    lhs_dims = None
    inline = _SHAPE_RE.search(rhs_part)
    om = _OPERAND_RE.search(rhs_part)
    if inline and (om is None or inline.start() < om.start()):
        # 'dot(f32[16,16]{1,0} %Arg_0.1, ...)': inline type precedes the name
        lhs_dims = [int(x) for x in inline.group(2).split(",") if x]
    elif om and om.group(1) in comp.shapes:
        sh = _parse_shape(comp.shapes[om.group(1)])
        if sh:
            lhs_dims = sh[0][1]
    k = 1
    if lhs_dims and cdims:
        for c in cdims:
            if c < len(lhs_dims):
                k *= lhs_dims[c]
    return 2.0 * n_out * k


def _trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    consts = []
    for op in cond.ops:
        consts += [int(c) for c in _CONST_RE.findall(op.line)]
    return max(consts) if consts else 1


_ZERO_BYTE_OPS = {"parameter", "tuple", "get-tuple-element", "bitcast",
                  "constant", "after-all", "partition-id", "replica-id"}


def _operands(op: Op) -> List[str]:
    args = op.line.split("(", 1)[1] if "(" in op.line else ""
    args = args.split("),")[0] if ")," in args else args
    return _OPERAND_RE.findall(args)


def _fusion_bytes(op: Op, comp: Computation,
                  comps: Dict[str, Computation]) -> float:
    """HBM traffic of a fusion call, aware of slicing/in-place semantics.

    A fused dynamic-slice reads only the slice; a fused dynamic-update-slice
    writes only the update (the output buffer aliases the input). Parameters
    consumed *only* through dynamic-slice contribute nothing beyond the slice.
    """
    m = _CALLS_RE.search(op.line)
    called = comps.get(m.group(1)) if m else None
    if called is None:
        return _shape_bytes(op.result)
    total = 0.0
    # which internal values are consumed only by dynamic-slice?
    sliced_only: Dict[str, bool] = {}
    for iop in called.ops:
        for o in _operands(iop):
            prev = sliced_only.get(o, True)
            sliced_only[o] = prev and iop.opcode == "dynamic-slice"
    root = next((o for o in called.ops if "ROOT" in o.line),
                called.ops[-1] if called.ops else None)
    root_is_dus = root is not None and root.opcode == "dynamic-update-slice"
    params = [i for i in called.ops if i.opcode == "parameter"
              and not sliced_only.get(i.name, False)]
    pbytes = [_shape_bytes(called.shapes.get(i.name, i.result)) for i in params]
    if root_is_dus and pbytes:
        # the in-place target buffer (reaches the root possibly via bitcasts)
        # is neither re-read nor re-written: drop the largest parameter.
        pbytes.remove(max(pbytes))
    total += sum(pbytes)
    for iop in called.ops:
        if iop.opcode == "dynamic-slice":
            total += 2.0 * _shape_bytes(iop.result)
        elif iop.opcode == "dynamic-update-slice":
            ops_ = _operands(iop)
            upd = called.shapes.get(ops_[1]) if len(ops_) > 1 else None
            total += 2.0 * _shape_bytes(upd) if upd else 0.0
    if root is not None and not root_is_dus:
        total += _shape_bytes(root.result)
    return total


def _op_bytes(op: Op, comp: Computation, comps: Dict[str, Computation]) -> float:
    if op.opcode in _ZERO_BYTE_OPS:
        return 0.0
    out_b = _shape_bytes(op.result)
    if op.opcode == "fusion":
        return _fusion_bytes(op, comp, comps)
    if op.opcode == "dynamic-update-slice":
        # in-place: traffic = read+write of the update slice, not the buffer.
        operands = _operands(op)
        upd = comp.shapes.get(operands[1]) if len(operands) > 1 else None
        return 2.0 * _shape_bytes(upd) if upd else out_b
    if op.opcode == "dynamic-slice":
        return 2.0 * out_b
    # generic: operands + output
    in_b = 0.0
    for o in _operands(op):
        if o in comp.shapes:
            in_b += _shape_bytes(comp.shapes[o])
    return in_b + out_b


def _dedupe_async(op: Op) -> Optional[str]:
    """Return collective kind for this op, counting -start but not -done."""
    for k in COLL_KINDS:
        if op.opcode == k or op.opcode == k + "-start":
            return k
    return None


def analyze(text: str) -> dict:
    comps = parse_module(text)
    entry = comps["__entry__"]
    totals = {"flops": 0.0, "bytes": 0.0,
              "coll": defaultdict(float), "coll_counts": defaultdict(float),
              "while_trips": [], "top_bytes": [], "top_flops": [],
              "coll_ops": []}

    def walk(comp: Computation, mult: float, count_bytes: bool, depth: int = 0):
        if depth > 50:
            return
        for op in comp.ops:
            if op.opcode == "dot":
                f = mult * _dot_flops(op, comp)
                totals["flops"] += f
                totals["top_flops"].append((f, op.opcode, op.line.strip()[:140]))
            kind = _dedupe_async(op)
            if kind:
                shape = op.result
                shps = _parse_shape(shape)
                if shape.startswith("(") and len(shps) > 1:
                    if op.opcode.endswith("-start"):
                        # async start returns (operand, result, ...)
                        b = sum(
                            _shape_bytes(f"{d}[{','.join(map(str, dims))}]")
                            for d, dims in shps[1:2])
                    else:
                        # multi-operand collective (decomposed all-to-all):
                        # the payload is ALL tuple members together
                        b = _shape_bytes(shape)
                else:
                    b = _shape_bytes(shape)
                totals["coll"][kind] += mult * b * COLL_FACTOR[kind]
                totals["coll_counts"][kind] += mult
                groups = collective_groups(op.line)
                gsize = len(groups[0]) if groups else 0
                totals["coll_ops"].append({
                    "kind": kind, "bytes": b,
                    "wire_bytes": _wire_bytes(kind, b, gsize),
                    "mult": mult, "groups": groups, "group_size": gsize,
                    "line": op.line.strip()[:400]})
            if count_bytes:
                b = mult * _op_bytes(op, comp, comps)
                totals["bytes"] += b
                if b > 0:
                    totals["top_bytes"].append((b, op.opcode, op.line.strip()[:140]))
            # --- recurse through the call graph ---
            if op.opcode == "while":
                m = _COND_BODY_RE.search(op.line)
                if m:
                    ktc = re.search(r'known_trip_count[^0-9]*(\d+)', op.line)
                    trips = (int(ktc.group(1)) if ktc
                             else _trip_count(comps, m.group(1)))
                    totals["while_trips"].append(trips)
                    body = comps.get(m.group(2))
                    if body:
                        walk(body, mult * trips, count_bytes, depth + 1)
            elif op.opcode == "fusion":
                m = _CALLS_RE.search(op.line)
                if m and m.group(1) in comps:
                    walk(comps[m.group(1)], mult, False, depth + 1)
            elif op.opcode in ("call", "async-start"):
                m = _TO_APPLY_RE.search(op.line) or _CALLS_RE.search(op.line)
                if m and m.group(1) in comps:
                    walk(comps[m.group(1)], mult, count_bytes, depth + 1)
            elif op.opcode == "conditional":
                m = _BRANCH_RE.search(op.line)
                if m:
                    for b in _OPERAND_RE.findall(m.group(1)):
                        if b in comps:
                            walk(comps[b], mult, count_bytes, depth + 1)

    walk(entry, 1.0, True)
    return {"flops": totals["flops"], "bytes": totals["bytes"],
            "top_bytes": sorted(totals["top_bytes"], reverse=True)[:40],
            "top_flops": sorted(totals["top_flops"], reverse=True)[:40],
            "collective": dict(totals["coll"]),
            "collective_total": sum(totals["coll"].values()),
            "collective_counts": dict(totals["coll_counts"]),
            "collective_ops": totals["coll_ops"],
            "while_trips": totals["while_trips"]}
