from repro.checkpoint.ckpt import (latest_step, restore, save,
                                   CheckpointManager)

__all__ = ["save", "restore", "latest_step", "CheckpointManager"]
