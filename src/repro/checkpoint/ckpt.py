"""Sharded, atomic, elastic checkpoints.

* atomic     — write to `<dir>/tmp.<step>`, fsync, `os.replace` to
               `<dir>/step_<n>`: a crash never leaves a half checkpoint
               visible (the trainer only ever restores complete steps).
* elastic    — leaves are saved as full logical arrays (assembled from
               shards); restore re-shards onto *any* mesh/device count via
               the provided shardings. A 512-chip run can resume on 256.
* manifest   — tree structure + shapes + dtypes, JSON, human-auditable.

Buffer donation on save path + free-asap mirrors Algorithm 1 step 5.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import ml_dtypes
import numpy as np

import jax

_EXTENDED = {"bfloat16": ml_dtypes.bfloat16,
             "float8_e4m3fn": ml_dtypes.float8_e4m3fn,
             "float8_e5m2": ml_dtypes.float8_e5m2}


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


def save(ckpt_dir: str, step: int, tree: Any, keep_last: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat, _ = _flatten(tree)
    manifest = {}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest[key] = {"file": fname, "shape": list(arr.shape),
                         "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "leaves": manifest}, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _gc(ckpt_dir, keep_last)
    return final


def _gc(ckpt_dir: str, keep_last: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    return int(steps[-1].split("_")[1]) if steps else None


def restore(ckpt_dir: str, step: int, target: Any,
            shardings: Any = None) -> Any:
    """Restore into `target`'s structure; reshard elastically if given."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)["leaves"]
    flat_t, treedef = _flatten(target)
    flat_s, _ = _flatten(shardings) if shardings is not None else ({}, None)
    leaves = []
    for key, leaf in flat_t.items():
        info = manifest[key]
        arr = np.load(os.path.join(d, info["file"]))
        if info["dtype"] in _EXTENDED:
            arr = arr.view(_EXTENDED[info["dtype"]])
        assert list(arr.shape) == list(leaf.shape), (key, arr.shape, leaf.shape)
        if key in flat_s and flat_s[key] is not None:
            leaves.append(jax.device_put(arr, flat_s[key]))  # elastic reshard
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    """Every-K-steps saver with optional async (background thread) writes."""

    def __init__(self, ckpt_dir: str, every: int = 100, keep_last: int = 3,
                 async_save: bool = True):
        self.dir, self.every, self.keep = ckpt_dir, every, keep_last
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    def maybe_save(self, step: int, tree: Any, force: bool = False):
        if not force and (self.every <= 0 or step % self.every != 0):
            return False
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)
        self.wait()
        if self.async_save:
            self._thread = threading.Thread(
                target=save, args=(self.dir, step, host_tree, self.keep))
            self._thread.start()
        else:
            save(self.dir, step, host_tree, self.keep)
        return True

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
