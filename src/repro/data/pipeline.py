"""Synthetic LM data pipeline with *localised placement*.

The pipeline is the data-path expression of the paper's technique: batches
are *born* locally homed through `Locale.make` — each device's batch chunk
is generated directly on (for) that device, never resharded after the fact
(Algorithm 1 steps 1-4 fused).  This is the same placement code path the
sort and the serving layer use; the pipeline constructs no shardings of
its own.

Determinism: batch content is a pure function of (seed, step, element row),
so a restart replays exactly the same batches — the property checkpoint
resume and straggler/failure recovery rely on.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ArchConfig
from repro.core.api import Locale


def _row_tokens(seed: int, step: int, row: int, seq_len: int,
                vocab: int) -> np.ndarray:
    """One deterministic 'document': a noisy arithmetic sequence (learnable)."""
    rng = np.random.RandomState((seed * 1_000_003 + step * 7919 + row)
                                % (2 ** 31 - 1))
    start = rng.randint(0, vocab)
    stride = rng.randint(1, 17)
    toks = (start + stride * np.arange(seq_len + 1)) % vocab
    noise = rng.rand(seq_len + 1) < 0.02
    toks = np.where(noise, rng.randint(0, vocab, seq_len + 1), toks)
    return toks.astype(np.int32)


@dataclass
class SyntheticLM:
    cfg: ArchConfig
    global_batch: int
    seq_len: int
    seed: int = 0
    mesh: Optional[Mesh] = None

    @property
    def locale(self) -> Locale:
        """Batch rows chunk-contiguous over the data-parallel axes.

        Mesh order is preserved, so on a (pod, data, model) mesh the axis
        tuple is ("pod", "data") — pod-major, matching the hierarchical
        engine's device linearisation: a pod's batch rows are contiguous
        and never born across the DCN boundary.
        """
        if self.mesh is None:
            return Locale(mesh=None)
        dp = tuple(a for a in self.mesh.axis_names if a != "model")
        return Locale(mesh=self.mesh, axis=dp)

    def batch(self, step: int) -> dict:
        B, S, V = self.global_batch, self.seq_len, self.cfg.vocab_size
        locale = self.locale

        built = {}

        def build(rows):
            # both callbacks see the same row range per device — build once
            key = (rows.start, rows.stop)
            if key not in built:
                built[key] = np.stack([_row_tokens(self.seed, step, r, S, V)
                                       for r in rows])
            return built[key]

        # localised placement: each device materialises only the rows it owns
        def cb(index):
            rows = range(*index[0].indices(B))
            return build(rows)[:, :-1]

        def cb_t(index):
            rows = range(*index[0].indices(B))
            return build(rows)[:, 1:]

        toks = locale.make((B, S), cb)
        tgts = locale.make((B, S), cb_t)
        batch = {"targets": jnp.asarray(tgts)}
        if self.cfg.embed_input:
            batch["tokens"] = jnp.asarray(toks)
        else:
            # stub frontend: frame embeddings derived deterministically
            t = np.asarray(toks)
            emb = (np.sin(t[..., None] * (1.0 + np.arange(self.cfg.d_model)))
                   / 8.0).astype(np.float32)
            batch["embeds"] = jnp.asarray(emb)
        if self.cfg.family == "vlm":
            rng = np.random.RandomState(self.seed * 31 + step)
            batch["image_embeds"] = jnp.asarray(
                rng.randn(B, self.cfg.num_image_tokens,
                          self.cfg.d_model).astype(np.float32) / 8.0)
        return batch


def make_batch_iterator(cfg, global_batch, seq_len, seed=0, mesh=None,
                        start_step: int = 0) -> Iterator[dict]:
    ds = SyntheticLM(cfg, global_batch, seq_len, seed, mesh)
    step = start_step
    while True:
        yield ds.batch(step)
        step += 1
