"""Synthetic LM data pipeline with *localised placement*.

The pipeline is the data-path expression of the paper's technique: batches
are *born* locally homed through `Locale.make` — each device's batch chunk
is generated directly on (for) that device, never resharded after the fact
(Algorithm 1 steps 1-4 fused).  This is the same placement code path the
sort and the serving layer use; the pipeline constructs no shardings of
its own.

Host-side generation is *striped* to match: every leaf of the batch
(tokens, targets, frame embeddings, image embeddings) is produced by a
per-row content function, and under ``striped=True`` (the default) each
device's callback materialises only the rows that device owns — the full
``(B, S[, D])`` array is never built on the host.  ``striped=False`` keeps
the old build-everything-then-place path as the bit-exact oracle;
``benchmarks/bench_striping.py --pipeline`` times the two against each
other (the ROADMAP's striping acceptance benchmark).

Determinism: batch content is a pure function of (seed, step, element row),
so a restart replays exactly the same batches — the property checkpoint
resume and straggler/failure recovery rely on.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ArchConfig
from repro.core.api import Locale


def _row_tokens(seed: int, step: int, row: int, seq_len: int,
                vocab: int) -> np.ndarray:
    """One deterministic 'document': a noisy arithmetic sequence (learnable)."""
    rng = np.random.RandomState((seed * 1_000_003 + step * 7919 + row)
                                % (2 ** 31 - 1))
    start = rng.randint(0, vocab)
    stride = rng.randint(1, 17)
    toks = (start + stride * np.arange(seq_len + 1)) % vocab
    noise = rng.rand(seq_len + 1) < 0.02
    toks = np.where(noise, rng.randint(0, vocab, seq_len + 1), toks)
    return toks.astype(np.int32)


def _embed_rows(toks: np.ndarray, d_model: int) -> np.ndarray:
    """Stub frontend: frame embeddings derived deterministically per row."""
    return (np.sin(toks[..., None] * (1.0 + np.arange(d_model)))
            / 8.0).astype(np.float32)


def _row_image_embeds(seed: int, step: int, row: int, n_tokens: int,
                      d_model: int) -> np.ndarray:
    """Per-row image stub — a function of (seed, step, row) like every other
    leaf, so image batches stripe over devices exactly like token batches."""
    rng = np.random.RandomState((seed * 31 + step * 7919 + row * 104_729)
                                % (2 ** 31 - 1))
    return (rng.randn(n_tokens, d_model) / 8.0).astype(np.float32)


@dataclass
class SyntheticLM:
    cfg: ArchConfig
    global_batch: int
    seq_len: int
    seed: int = 0
    mesh: Optional[Mesh] = None
    striped: bool = True    # per-device generation; False = host-build oracle

    @property
    def locale(self) -> Locale:
        """Batch rows chunk-contiguous over the data-parallel axes.

        Mesh order is preserved, so on a (pod, data, model) mesh the axis
        tuple is ("pod", "data") — pod-major, matching the hierarchical
        engine's device linearisation: a pod's batch rows are contiguous
        and never born across the DCN boundary.
        """
        if self.mesh is None:
            return Locale(mesh=None)
        dp = tuple(a for a in self.mesh.axis_names if a != "model")
        return Locale(mesh=self.mesh, axis=dp)

    def batch(self, step: int) -> dict:
        B, S, V = self.global_batch, self.seq_len, self.cfg.vocab_size
        locale = self.locale
        if not self.striped:
            return self._host_batch(step, locale)

        built = {}

        def build(rows):
            # every callback sees the same row range per device — build once
            key = (rows.start, rows.stop)
            if key not in built:
                built[key] = np.stack([_row_tokens(self.seed, step, r, S, V)
                                       for r in rows])
            return built[key]

        def rows_of(index):
            return range(*index[0].indices(B))

        # localised generation: each device materialises only the rows it
        # owns — for every leaf, including the (B, S, D) embedding stripes
        def cb(index):
            return build(rows_of(index))[:, :-1]

        def cb_t(index):
            return build(rows_of(index))[:, 1:]

        batch = {"targets": locale.make((B, S), cb_t)}
        if self.cfg.embed_input:
            batch["tokens"] = locale.make((B, S), cb)
        else:
            def cb_e(index):
                return _embed_rows(build(rows_of(index))[:, :-1],
                                   self.cfg.d_model)

            batch["embeds"] = locale.make((B, S, self.cfg.d_model), cb_e)
        if self.cfg.family == "vlm":
            T, D = self.cfg.num_image_tokens, self.cfg.d_model

            def cb_i(index):
                return np.stack([_row_image_embeds(self.seed, step, r, T, D)
                                 for r in rows_of(index)])

            batch["image_embeds"] = locale.make((B, T, D), cb_i)
        return batch

    def _host_batch(self, step: int, locale: Locale) -> dict:
        """The pre-striping oracle: build every full array on the host, then
        place it.  Same per-row content functions, so `striped=True` must
        reproduce it bit-exactly; kept for the acceptance benchmark."""
        B, S, V = self.global_batch, self.seq_len, self.cfg.vocab_size

        def place(a: np.ndarray):
            if locale.mesh is None:
                return jnp.asarray(a)
            return jax.device_put(a, locale.sharding(a.ndim))

        full = np.stack([_row_tokens(self.seed, step, r, S, V)
                         for r in range(B)])
        batch = {"targets": place(full[:, 1:])}
        if self.cfg.embed_input:
            batch["tokens"] = place(full[:, :-1])
        else:
            batch["embeds"] = place(_embed_rows(full[:, :-1],
                                                self.cfg.d_model))
        if self.cfg.family == "vlm":
            T, D = self.cfg.num_image_tokens, self.cfg.d_model
            batch["image_embeds"] = place(
                np.stack([_row_image_embeds(self.seed, step, r, T, D)
                          for r in range(B)]))
        return batch


def make_batch_iterator(cfg, global_batch, seq_len, seed=0, mesh=None,
                        start_step: int = 0, striped: bool = True
                        ) -> Iterator[dict]:
    ds = SyntheticLM(cfg, global_batch, seq_len, seed, mesh, striped)
    step = start_step
    while True:
        yield ds.batch(step)
        step += 1
