"""granite-3-2b [dense] — GQA. [hf:ibm-granite/granite-3.0-2b-base; hf]"""
from repro.configs.base import ArchConfig, ParallelConfig, register

CONFIG = register(ArchConfig(
    name="granite-3-2b",
    family="dense",
    num_layers=40,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    head_dim=64,               # 2048 / 32
    d_ff=8192,
    vocab_size=49155,          # not 256-aligned -> padded internally to 49408
    parallel=ParallelConfig(fsdp=False, microbatches=1),
))
