"""llama-3.2-vision-90b [vlm] — cross-attn image layers; vision tower is a
STUB (input_specs provides precomputed patch embeddings).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]

100 layers total = 80 self-attention + 20 cross-attention (1 per 5).
"""
from repro.configs.base import ArchConfig, ParallelConfig, register

CONFIG = register(ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=5e5,
    cross_attn_every=5,
    num_image_tokens=4096,     # precomputed patch embeddings (stub frontend)
    parallel=ParallelConfig(fsdp=True, microbatches=16),
))
