"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave, MoE 16e top-2.
[arXiv:2403.19887; hf]

Superblock of 8 layers: 1 attention + 7 mamba; MoE FFN on every other layer
(36 MoE layers of 16 experts -> ~398B total params, ~94B active).
"""
from repro.configs.base import ArchConfig, ParallelConfig, register

CONFIG = register(ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    num_experts=16,            # divides the 16-way model axis: true EP
    top_k=2,
    moe_every=2,
    attn_every=8,              # 1:7 attention:mamba interleave
    ssm_state=128,
    ssm_headdim=128,           # d_inner = 16384 -> 128 SSD heads
    ssm_expand=2,
    ssm_conv=4,
    ssm_ngroups=8,
    subquadratic=True,         # hybrid: SSM layers linear; few attn layers CP-sharded
    parallel=ParallelConfig(fsdp=True, microbatches=4, zero1=True),
))
