"""musicgen-medium [audio] — decoder-only over EnCodec tokens; the EnCodec
frontend is a STUB (input_specs provides precomputed frame embeddings).
[arXiv:2306.05284; hf]

24 heads do not divide the 16-way model axis -> attention runs
head-replicated; TP applies to the FFN + vocab head (see DESIGN.md §4).
"""
from repro.configs.base import ArchConfig, ParallelConfig, register

CONFIG = register(ArchConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,           # EnCodec codebook
    embed_input=False,         # frontend stub: inputs are frame embeddings
    parallel=ParallelConfig(fsdp=False, microbatches=2),
))
