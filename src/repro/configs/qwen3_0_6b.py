"""qwen3-0.6b [dense] — qk_norm, GQA. [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.base import ArchConfig, ParallelConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-0.6b",
    family="dense",
    num_layers=28,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,              # decoupled head_dim (16*128 != d_model), as in HF
    d_ff=3072,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1e6,
    parallel=ParallelConfig(fsdp=False, microbatches=1),
))
