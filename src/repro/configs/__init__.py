"""Arch registry — importing this package registers every assigned config."""
from repro.configs.base import (ArchConfig, ParallelConfig, ShapeSpec, SHAPES,
                                get_config, list_configs, reduce_config, register)

# one module per assigned architecture (+ the paper's own workload)
from repro.configs import (  # noqa: F401
    mixtral_8x7b,
    deepseek_moe_16b,
    qwen3_0_6b,
    glm4_9b,
    granite_20b,
    granite_3_2b,
    musicgen_medium,
    mamba2_2_7b,
    jamba_1_5_large_398b,
    llama_3_2_vision_90b,
    paper_sort,
)

ARCH_NAMES = [
    "mixtral-8x7b", "deepseek-moe-16b", "qwen3-0.6b", "glm4-9b",
    "granite-20b", "granite-3-2b", "musicgen-medium", "mamba2-2.7b",
    "jamba-1.5-large-398b", "llama-3.2-vision-90b",
]

__all__ = ["ArchConfig", "ParallelConfig", "ShapeSpec", "SHAPES", "get_config",
           "list_configs", "reduce_config", "register", "ARCH_NAMES"]
