"""Config dataclasses + arch registry.

Every assigned architecture is a frozen `ArchConfig` built from the exact
figures in the assignment brief. `reduce_config` derives the tiny smoke-test
variant of the same family; the full configs are exercised only through the
dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


def pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned input shape (seq_len x global_batch)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class ParallelConfig:
    """Distribution knobs — the 'static mapping' side of the paper's technique.

    Everything is explicit: parameter layouts, activation layouts and the
    data-chunk ownership are all chosen statically (never left to the
    runtime), mirroring the paper's static thread->core mapping.
    """

    fsdp: bool = False              # shard params over the dp axes too (ZeRO-3 style)
    sequence_shard: bool = True     # SP: residual stream seq-sharded over model axis
    zero1: bool = False             # optimizer state sharded over dp axes
    remat: bool = True              # per-(super)block activation rematerialisation
    microbatches: int = 1           # gradient-accumulation steps inside train_step
    grad_compression: bool = False  # int8 + error-feedback DP all-reduce
    accum_via_scan_grad: bool = False  # differentiate through the microbatch
                                       # scan: one grad reduction per step
    accum_dtype: str = "float32"


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # --- attention details ---
    qk_norm: bool = False
    sliding_window: int = 0         # 0 = full attention
    rope_theta: float = 10000.0
    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_every: int = 1              # a MoE FFN every `moe_every` layers (others dense)
    capacity_factor: float = 1.25
    # --- SSM (mamba2 / hybrid) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_ngroups: int = 1
    attn_every: int = 0             # hybrid: 1 attention layer per attn_every layers
    # --- modality frontends (stubbed per brief) ---
    embed_input: bool = True        # False -> inputs are precomputed embeddings
    cross_attn_every: int = 0       # vlm: 1 cross-attn layer per N layers
    num_image_tokens: int = 0
    # --- numerics ---
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    # --- distribution ---
    parallel: ParallelConfig = field(default_factory=ParallelConfig)
    # --- long-context applicability (sub-quadratic attention available?) ---
    subquadratic: bool = False

    # ---------- derived ----------
    @property
    def vocab_padded(self) -> int:
        return pad_to(self.vocab_size, 256)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_headdim else 0

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def attn_layers(self) -> Tuple[int, ...]:
        """Indices (within the full stack) that are attention layers."""
        if self.family == "ssm":
            return ()
        if self.attn_every:
            return tuple(i for i in range(self.num_layers) if i % self.attn_every == 0)
        return tuple(range(self.num_layers))

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ---------- parameter count (for MODEL_FLOPS = 6*N*D) ----------
    def param_counts(self) -> dict[str, float]:
        """Analytic parameter counts: total and active-per-token."""
        D, H, KV, hd, F, V = (self.d_model, self.num_heads, self.num_kv_heads,
                              self.head_dim, self.d_ff, self.vocab_padded)
        attn = D * H * hd + 2 * D * KV * hd + H * hd * D
        dense_ffn = 3 * D * F
        moe_ffn = self.num_experts * 3 * D * F
        shared_ffn = self.num_shared_experts * 3 * D * F
        active_moe = (self.top_k + self.num_shared_experts) * 3 * D * F
        if self.family == "ssm" or self.attn_every:
            di, G, N, Hs = self.d_inner, self.ssm_ngroups, self.ssm_state, self.ssm_nheads
            mamba = (2 * D * di + 2 * D * G * N + D * Hs  # in projections
                     + self.ssm_conv * (di + 2 * G * N)   # conv
                     + 2 * Hs + di                        # A, dt_bias, norm-ish
                     + di * D)                            # out proj
        else:
            mamba = 0
        total = active = 0
        for i in range(self.num_layers):
            is_attn = (i in self.attn_layers) if (self.attn_every or self.family == "ssm") else True
            if self.family == "ssm":
                total += mamba; active += mamba
                continue
            lyr = attn if is_attn else mamba
            if self.is_moe and (i % self.moe_every == self.moe_every - 1 or self.moe_every == 1):
                ffn_t, ffn_a = moe_ffn + shared_ffn, active_moe
            else:
                ffn_t = ffn_a = dense_ffn
            if self.cross_attn_every and i % self.cross_attn_every == self.cross_attn_every - 1:
                lyr += attn  # extra cross-attention block
            total += lyr + ffn_t + 2 * D
            active += lyr + ffn_a + 2 * D
        emb = V * D * (1 if not self.embed_input else 2)  # in-embed + head (untied)
        total += emb + D
        active += emb + D
        return {"total": float(total), "active": float(active)}


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        from repro import configs as _c  # noqa: F401  (populates registry)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    from repro import configs as _c  # noqa: F401
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# reduced (smoke-test) variants — same family/pattern, tiny sizes
# ---------------------------------------------------------------------------
def reduce_config(cfg: ArchConfig, *, layers: Optional[int] = None) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests."""
    block = max(cfg.attn_every, cfg.cross_attn_every, cfg.moe_every, 1)
    n_layers = layers if layers is not None else 2 * block
    kv = 2 if cfg.num_kv_heads > 1 else 1
    return cfg.replace(
        num_layers=n_layers,
        d_model=64,
        num_heads=4,
        num_kv_heads=kv,
        head_dim=16,
        d_ff=0 if cfg.family == "ssm" else 128,
        vocab_size=503,  # deliberately not a multiple of 256 -> exercises padding
        num_experts=4 if cfg.num_experts else 0,
        num_shared_experts=min(cfg.num_shared_experts, 1),
        top_k=min(cfg.top_k, 2),
        # no-drop capacity: GShard capacity dropping is not causal, so parity
        # tests (decode == forward) need C == group size. Dropping semantics
        # are covered separately in test_moe.py.
        capacity_factor=2.0 if cfg.num_experts else 1.25,
        sliding_window=16 if cfg.sliding_window else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_headdim=16 if cfg.ssm_state else 64,
        num_image_tokens=24 if cfg.num_image_tokens else 0,
        dtype="float32",
        param_dtype="float32",
        parallel=ParallelConfig(fsdp=False, sequence_shard=False, remat=False),
    )
