"""The paper's own workload: parallel merge sort over int32 arrays.

Table 1 cases = {localised, non-localised} x {static, runtime mapping} x
{local-homing (chunk-contiguous), hash-for-home (interleaved)}.
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class SortConfig:
    name: str = "paper-sort"
    array_size: int = 100_000_000   # paper Fig 2: 100M ints
    micro_array_size: int = 1_000_000  # paper Fig 1: 1M ints
    dtype: str = "int32"
    localised: bool = True          # copy chunks into locally-homed buffers
    static_mapping: bool = True     # explicit chunk->device ownership
    homing: str = "local"           # "local" (chunked) | "hash" (interleaved)


CASES = {
    # paper Table 1 (mapper "Tile Linux" == runtime-chosen layout;
    # hash "All but stack" == interleaved; "None" == local homing)
    1: SortConfig(localised=False, static_mapping=False, homing="hash"),
    2: SortConfig(localised=False, static_mapping=False, homing="local"),
    3: SortConfig(localised=False, static_mapping=True, homing="hash"),
    4: SortConfig(localised=False, static_mapping=True, homing="local"),
    5: SortConfig(localised=True, static_mapping=False, homing="hash"),
    6: SortConfig(localised=True, static_mapping=False, homing="local"),
    7: SortConfig(localised=True, static_mapping=True, homing="hash"),
    8: SortConfig(localised=True, static_mapping=True, homing="local"),
}
