"""mixtral-8x7b [moe] — 8 experts top-2, SWA. [arXiv:2401.04088; hf]"""
from repro.configs.base import ArchConfig, ParallelConfig, register

CONFIG = register(ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    num_experts=8,
    top_k=2,
    sliding_window=4096,
    rope_theta=1e6,
    subquadratic=True,  # sliding-window attention -> ring-buffer KV
    # 8 experts do not divide the 16-way model axis -> experts replicated over
    # TP, FFN dim TP-sharded, and FSDP over dp axes carries the memory.
    parallel=ParallelConfig(fsdp=True, microbatches=8),
))
