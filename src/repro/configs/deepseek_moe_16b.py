"""deepseek-moe-16b [moe] — 2 shared + 64 routed top-6, fine-grained experts.
[arXiv:2401.06066; hf]"""
from repro.configs.base import ArchConfig, ParallelConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,                 # fine-grained expert width
    vocab_size=102400,
    num_experts=64,            # divides the 16-way model axis: true EP
    num_shared_experts=2,
    top_k=6,
    parallel=ParallelConfig(fsdp=False, microbatches=4),
))
