"""mamba2-2.7b [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]"""
from repro.configs.base import ArchConfig, ParallelConfig, register

CONFIG = register(ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,                    # attention-free, no separate FFN (SSD blocks only)
    vocab_size=50280,
    ssm_state=128,
    ssm_headdim=64,            # d_inner = 5120 -> 80 SSD heads
    ssm_expand=2,
    ssm_conv=4,
    ssm_ngroups=1,
    subquadratic=True,         # O(1)-state decode, chunked linear-time prefill
    parallel=ParallelConfig(fsdp=False, microbatches=1),
))
