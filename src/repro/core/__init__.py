"""The paper's primary contribution: cache/locality-aware placement.

Public surface (`repro.core.api`):

- `Locale`  — (mesh, axis, policy) as one object: `put`, `pin`, `localise`,
              `pin_tree`, `jit`, `make`, and the `workload(...)` factory.
- `Homed`   — an array carrying its homing as pytree metadata; `.logical()`
              recovers logical order, mixed homings are tree-structure errors.

Building blocks (still first-class):

- `homing`       — layout mechanics (local homing vs hash-for-home)
- `localisation` — Algorithm 1/2: `LocalisationPolicy`, `chunk_bounds`
- `sort`         — distributed parallel merge sort (the validation app)
- `engine`       — the explicit shard_map execution backend (Algorithms 1-3)
- `microbench`   — the Fig-1 repetitive-copy micro-benchmark

The pre-`Locale` free functions (`to_layout`, `constrain`, `logical_view`,
`localise`, `place`) and per-workload factories (`make_sort_fn`,
`make_engine_fn`, `make_microbench_fn`) remain importable from here as thin
deprecation shims only.
"""
import warnings as _warnings

from repro.core import engine as _engine
from repro.core import homing as _homing
from repro.core import localisation as _localisation
from repro.core import microbench as _microbench
from repro.core import sort as _sort
from repro.core.api import Homed, Locale, register_workload
from repro.core.homing import Homing, check_divisible
from repro.core.localisation import LocalisationPolicy, chunk_bounds
from repro.core.sort import (BACKENDS, check_nan_free, distributed_merge_sort,
                             merge_sorted, pad_to_multiple, pad_value)
from repro.core.engine import (LOCAL_PHASES, exchange_schedule,
                               shard_map_sort)
from repro.core.microbench import repetitive_copy


def _deprecated(name: str, fn, repl: str):
    def shim(*args, **kw):
        _warnings.warn(
            f"repro.core.{name} is deprecated; use {repl} (repro.core.api)",
            DeprecationWarning, stacklevel=2)
        return fn(*args, **kw)
    shim.__name__ = name
    shim.__qualname__ = name
    shim.__doc__ = f"Deprecated shim for {repl}.\n\n{fn.__doc__ or ''}"
    return shim


to_layout = _deprecated("to_layout", _homing.to_layout, "Locale.put")
constrain = _deprecated("constrain", _homing.constrain, "Locale.pin")
logical_view = _deprecated("logical_view", _homing.logical_view,
                           "Homed.logical")
localise = _deprecated("localise", _localisation.localise, "Locale.localise")
place = _deprecated("place", _localisation.place, "Locale.pin")
make_sort_fn = _deprecated("make_sort_fn", _sort.make_sort_fn,
                           'Locale.workload("sort", backend=...)')
make_engine_fn = _deprecated("make_engine_fn", _engine.make_engine_fn,
                             'Locale.workload("sort", backend="shard_map")')
make_microbench_fn = _deprecated("make_microbench_fn",
                                 _microbench.make_microbench_fn,
                                 'Locale.workload("microbench", reps=...)')

__all__ = ["Locale", "Homed", "register_workload",
           "Homing", "check_divisible",
           "LocalisationPolicy", "chunk_bounds",
           "BACKENDS", "check_nan_free", "distributed_merge_sort",
           "merge_sorted", "pad_to_multiple", "pad_value",
           "LOCAL_PHASES", "exchange_schedule", "shard_map_sort",
           "repetitive_copy",
           # deprecated shims
           "to_layout", "constrain", "logical_view", "localise", "place",
           "make_sort_fn", "make_engine_fn", "make_microbench_fn"]
