"""The paper's primary contribution: cache/locality-aware placement.

- `homing`       — layout policies (local homing vs hash-for-home)
- `localisation` — Algorithm 1/2: chunk ownership, localise(), donation
- `sort`         — distributed parallel merge sort (the validation app)
- `microbench`   — the Fig-1 repetitive-copy micro-benchmark
"""
from repro.core.homing import Homing, to_layout, constrain, logical_view
from repro.core.localisation import (LocalisationPolicy, chunk_bounds,
                                     localise, place)
from repro.core.sort import distributed_merge_sort, make_sort_fn, merge_sorted
from repro.core.microbench import repetitive_copy, make_microbench_fn

__all__ = ["Homing", "to_layout", "constrain", "logical_view",
           "LocalisationPolicy", "chunk_bounds", "localise", "place",
           "distributed_merge_sort", "make_sort_fn", "merge_sorted",
           "repetitive_copy", "make_microbench_fn"]
