"""The paper's primary contribution: cache/locality-aware placement.

- `homing`       — layout policies (local homing vs hash-for-home)
- `localisation` — Algorithm 1/2: chunk ownership, localise(), donation
- `sort`         — distributed parallel merge sort (the validation app)
- `engine`       — the explicit shard_map execution backend (Algorithms 1-3)
- `microbench`   — the Fig-1 repetitive-copy micro-benchmark
"""
from repro.core.homing import Homing, to_layout, constrain, logical_view
from repro.core.localisation import (LocalisationPolicy, chunk_bounds,
                                     localise, place)
from repro.core.sort import (BACKENDS, distributed_merge_sort, make_sort_fn,
                             merge_sorted, pad_to_multiple, pad_value)
from repro.core.engine import make_engine_fn, shard_map_sort
from repro.core.microbench import repetitive_copy, make_microbench_fn

__all__ = ["Homing", "to_layout", "constrain", "logical_view",
           "LocalisationPolicy", "chunk_bounds", "localise", "place",
           "BACKENDS", "distributed_merge_sort", "make_sort_fn",
           "merge_sorted", "pad_to_multiple", "pad_value",
           "make_engine_fn", "shard_map_sort",
           "repetitive_copy", "make_microbench_fn"]
