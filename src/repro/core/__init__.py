"""The paper's primary contribution: cache/locality-aware placement.

Public surface (`repro.core.api`):

- `Locale`  — (mesh, axis, policy) as one object: `put`, `pin`, `localise`,
              `pin_tree`, `jit`, `make`, and the `workload(...)` factory.
- `Homed`   — an array carrying its homing as pytree metadata; `.logical()`
              recovers logical order, mixed homings are tree-structure errors.

Building blocks (still first-class):

- `homing`       — layout mechanics (local homing vs hash-for-home)
- `localisation` — Algorithm 1/2: `LocalisationPolicy`, `chunk_bounds`
- `sort`         — distributed parallel merge sort (the validation app)
- `engine`       — the explicit shard_map execution backend (Algorithms 1-3)
- `microbench`   — the Fig-1 repetitive-copy micro-benchmark

The pre-`Locale` free functions (`to_layout`, `constrain`, `logical_view`,
`localise`, `place`) and per-workload factories (`make_sort_fn`,
`make_engine_fn`, `make_microbench_fn`) lived here as deprecation shims
for two PRs and are now gone: use `Locale`/`Homed`, or import the
building block from its own module (`repro.core.homing`,
`repro.core.localisation`, `repro.core.sort`, `repro.core.engine`,
`repro.core.microbench`) when you really want the mechanics.  Workload
discovery (`repro.analysis` homecheck, `Locale.workload`) sees only the
`register_workload` registry.
"""
from repro.core.api import (Homed, Locale, register_workload,
                            workload_names)
from repro.core.homing import Homing, check_divisible
from repro.core.localisation import LocalisationPolicy, chunk_bounds
from repro.core.sort import (BACKENDS, check_nan_free, distributed_merge_sort,
                             merge_sorted, pad_to_multiple, pad_value)
from repro.core.engine import (LOCAL_PHASES, collective_census,
                               exchange_schedule, shard_map_sort)
from repro.core.microbench import repetitive_copy

__all__ = ["Locale", "Homed", "register_workload", "workload_names",
           "Homing", "check_divisible",
           "LocalisationPolicy", "chunk_bounds",
           "BACKENDS", "check_nan_free", "distributed_merge_sort",
           "merge_sorted", "pad_to_multiple", "pad_value",
           "LOCAL_PHASES", "collective_census", "exchange_schedule",
           "shard_map_sort",
           "repetitive_copy"]
