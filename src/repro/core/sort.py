"""Distributed parallel merge sort — the paper's validation application.

Structure mirrors Algorithm 3: a local sort per worker (the
`mergesort_serial` leaves) followed by a log2(N)-level merge reduction tree.
The merge itself is the classic searchsorted rank-merge (log-depth, fully
vectorised — no data-dependent control flow, so it jits cleanly).

The paper's Table 1 axes map to:
  * homing      — input layout: chunk-contiguous vs hash-interleaved
  * localised   — one-shot `localise()` relayout before compute vs leaving
                  every tree level pinned to the hash layout (repeated
                  remote traffic, one all-to-all per level)
  * static      — explicit layout constraints everywhere vs letting the
                  compiler/runtime decide (the Tile-Linux-scheduler analogue)
"""
from __future__ import annotations

import functools
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.homing import Axis, Homing, axis_size
from repro.core.localisation import LocalisationPolicy

BIG = {jnp.dtype("int32"): jnp.iinfo(jnp.int32).max,
       jnp.dtype("float32"): jnp.inf}

BACKENDS = ("constraint", "shard_map")


def check_nan_free(x, where: str) -> None:
    """Raise a clear ValueError if a concrete float array contains NaN.

    NaN breaks both halves of the sort: it compares unordered in the
    searchsorted rank merge, and it sorts *after* the inf BIG sentinel, so
    the post-sort tail strip would keep a sentinel and silently drop the
    NaN.  Only concrete arrays can be inspected — inside a trace (jit) the
    guard is a no-op, which is why the jitted sort entry points check their
    (always concrete) inputs eagerly via `sort_entry` before dispatching.
    """
    if isinstance(x, jax.core.Tracer):
        return
    if not jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating):
        return
    bad = int(jnp.isnan(x).sum())
    if bad:
        raise ValueError(
            f"{where}: input contains {bad} NaN value(s) — NaN is unordered "
            f"under the rank merge and sorts after the inf padding sentinel, "
            f"so the result would silently drop it; filter NaNs out (e.g. "
            f"x[~jnp.isnan(x)]) or sort with jnp.sort directly")


def sort_entry(jitted, granule: int):
    """NaN-guard + eager-pad wrapper around a jitted sort.

    The wrapper sees the caller's concrete array *before* jit tracing, so
    `check_nan_free` can actually raise, and the BIG-sentinel padding to
    `granule` happens eagerly — GSPMD's partitioned concatenate mis-compiles
    on meshes with a >1-size axis outside the sort axis (padded elements
    arrive summed across it), so the traced fn must only ever see
    already-granular inputs; its internal `pad_to_multiple` then no-ops.
    The sentinel tail is stripped eagerly after the call.  `.lower` (used by
    the HLO structure benchmarks/tests) passes through to the jitted fn.
    """
    @functools.wraps(getattr(jitted, "__wrapped__", jitted))
    def call(x, *args, **kw):
        x = jnp.asarray(x)              # jit coerced sequences; keep doing so
        check_nan_free(x, "sort")       # pad skips its own scan: one pass
        n = x.shape[0]
        return jitted(pad_to_multiple(x, granule, nan_check=False),
                      *args, **kw)[:n]
    call.lower = jitted.lower
    call.__wrapped__ = jitted
    return call


def pad_value(dtype):
    """Sort-neutral sentinel: sorts after every real element of `dtype`."""
    dt = jnp.dtype(dtype)
    if dt in BIG:
        return BIG[dt]
    if jnp.issubdtype(dt, jnp.floating):
        return jnp.inf
    return jnp.iinfo(dt).max


def pad_to_multiple(x, m: int, nan_check: bool = True):
    """Pad a 1-D array with BIG sentinels up to the next multiple of m.

    Sentinels sort after (or tie with) every real element, so after sorting
    the original multiset occupies the first `len(x)` slots — the caller
    strips them with `out[:len(x)]`.

    Float inputs must be NaN-free when padding occurs: NaN sorts after the
    inf sentinel, so the tail strip would keep a sentinel and silently drop
    the NaN (the searchsorted rank merge is NaN-unsound anyway).  Concrete
    float inputs are checked and raise ValueError (``nan_check=False`` for
    callers that already checked); traced inputs rely on the jitted entry
    points' eager `sort_entry` guard.
    """
    n = x.shape[0]
    n_pad = (-n) % m
    if n_pad == 0:
        return x
    if nan_check:
        check_nan_free(x, "pad_to_multiple")
    fill = jnp.full((n_pad,), pad_value(x.dtype), x.dtype)
    return jnp.concatenate([x, fill])


def constraint_granule(mesh: Optional[Mesh], policy: LocalisationPolicy,
                       num_workers: Optional[int], axis: Axis) -> int:
    """The constraint backend's padding granule: a chunk per worker, and —
    under static mapping — every merge level's run size divisible by the
    mesh axis, so no level falls back to a replicate constraint (the
    static-mapping policy promises an explicit layout at *every* level).
    The one definition shared by `distributed_merge_sort` (in-trace no-op
    re-pad) and `make_sort_fn` (the eager pad that must match it).
    """
    m = num_workers or (axis_size(mesh, axis) if mesh is not None else 8)
    if mesh is not None and policy.static_mapping:
        return m * axis_size(mesh, axis)
    return m


def check_pad_outside_trace(n: int, granule: int, mesh: Optional[Mesh],
                            axis: Axis, where: str) -> None:
    """Trace-time guard: in-jit sentinel padding is unsafe on some meshes.

    GSPMD mis-partitions the padding `concatenate` when the mesh has a
    >1-size axis outside the sort axis (padded elements arrive *summed*
    across it — silently wrong results).  All lengths/axis sizes are static,
    so this raises at trace time in exactly the dangerous corner; the
    blessed entry points (`make_sort_fn` / `Locale.workload`) pre-pad
    eagerly via `sort_entry` and never trip it.
    """
    if mesh is None or n % granule == 0:
        return
    if mesh.devices.size > axis_size(mesh, axis):
        raise ValueError(
            f"{where}: input length {n} needs in-trace sentinel padding to a "
            f"multiple of {granule}, but mesh axes outside {axis!r} have "
            f"size > 1 — GSPMD mis-partitions the padding concatenate there "
            f"(elements summed across the unrelated axis). Pre-pad with "
            f"pad_to_multiple(x, {granule}) outside jit, or call through "
            f"make_sort_fn / Locale.workload, which pad eagerly.")


def merge_sorted(a, b):
    """Merge two sorted 1-D arrays (stable, duplicate-safe rank merge).

    Gather form: `ia` (each a-element's output position, strictly
    increasing) is inverted with one more searchsorted, so every output
    element is *read* from a or b rather than scattered into place.
    Scatter-free on purpose — GSPMD mis-partitions chained set-scatters on
    meshes with an unrelated >1-size axis (elements arrive summed across
    it), which the constraint backend's sharded merge tree would trip.
    """
    na, nb = a.shape[-1], b.shape[-1]
    if na == 0 or nb == 0:              # static shapes: nothing to interleave
        return jnp.concatenate([a, b], axis=-1)
    ia = jnp.arange(na) + jnp.searchsorted(b, a, side="left")
    k = jnp.arange(na + nb)
    ra = jnp.searchsorted(ia, k, side="left")    # a-elements placed before k
    ra_c = jnp.minimum(ra, na - 1)
    is_a = (ra < na) & (jnp.take(ia, ra_c) == k)
    rb = jnp.clip(k - ra, 0, nb - 1)
    return jnp.where(is_a, jnp.take(a, ra_c), jnp.take(b, rb))


_merge_rows = jax.vmap(merge_sorted)


def _constrain_runs(runs, mesh: Optional[Mesh], policy: LocalisationPolicy,
                    axis: Axis = "data"):
    """Layout the (count, size) run matrix per policy, between tree levels."""
    if mesh is None or not policy.static_mapping:
        return runs
    N = axis_size(mesh, axis)
    count, size = runs.shape
    if not policy.localised and policy.homing == Homing.LOCAL_CHUNKED:
        # paper case 2/4: the conventional code under local homing — the whole
        # array is homed where it was created (one tile), every worker reads
        # remotely. Pod analogue: full replication (broadcast per level).
        return jax.lax.with_sharding_constraint(
            runs, NamedSharding(mesh, P(None, None)))
    if policy.localised:
        # each run homed on its leader's device (chunk-contiguous rows)
        spec = P(axis, None) if count % N == 0 else P(None, axis) \
            if size % N == 0 else P(None, None)
        return jax.lax.with_sharding_constraint(runs, NamedSharding(mesh, spec))
    # hash-for-home: every run striped element-wise across all devices
    if size % N == 0:
        r = runs.reshape(count, size // N, N)
        r = jax.lax.with_sharding_constraint(
            r, NamedSharding(mesh, P(None, None, axis)))
        return r.reshape(count, size)
    return runs


def distributed_merge_sort(x, mesh: Optional[Mesh] = None,
                           policy: LocalisationPolicy = LocalisationPolicy(),
                           num_workers: Optional[int] = None,
                           local_sort: Callable = jnp.sort,
                           axis: Axis = "data"):
    """Sort a 1-D array with an m-worker merge tree (m = #devices default).

    Arbitrary lengths are supported: the input is padded with BIG sentinels
    up to the next multiple of `constraint_granule(...)` and the padding is
    stripped after the tree.  Float inputs must be NaN-free (see
    `pad_to_multiple`).  On meshes with a >1-size axis outside `axis`,
    non-granular lengths must be pre-padded outside jit (`make_sort_fn` /
    `Locale.workload` do this; `check_pad_outside_trace` rejects the rest).
    """
    n = x.shape[0]
    m = num_workers or (axis_size(mesh, axis) if mesh is not None else 8)
    assert (m & (m - 1)) == 0, m

    granule = constraint_granule(mesh, policy, num_workers, axis)
    check_pad_outside_trace(n, granule, mesh, axis, "distributed_merge_sort")
    x = pad_to_multiple(x, granule)
    runs = x.reshape(m, x.shape[0] // m)
    runs = _constrain_runs(runs, mesh, policy, axis)
    runs = local_sort(runs, axis=-1)                 # leaves of the tree
    runs = _constrain_runs(runs, mesh, policy, axis)
    while runs.shape[0] > 1:
        merged = _merge_rows(runs[0::2], runs[1::2])
        runs = _constrain_runs(merged, mesh, policy, axis)
    return runs[0][:n]


def make_sort_fn(mesh, policy: LocalisationPolicy, num_workers=None,
                 local_sort=None, backend: str = "constraint",
                 axis: Axis = "data", interpret: bool = True,
                 local_phase: str = None):
    """Jitted sort for one Table-1 case; input buffer donated (step 5).

    backend="constraint": the original `with_sharding_constraint`-hint tree —
    layout is *suggested* and the XLA SPMD partitioner picks the collectives.
    backend="shard_map": the explicit per-device execution engine
    (`repro.core.engine`) — ownership, local Pallas sort and inter-device
    exchange are spelled out literally (paper Algorithms 1-3).

    `local_sort=None` picks the backend default (jnp.sort for the hint
    backend, the Pallas bitonic kernel for the engine).  `local_phase`
    selects the engine's per-device compute: "pallas" (fused VMEM-resident
    local-sort + kept-half merge-split kernels), "reference" (the jnp
    oracle), or None = auto by `local_sort` — engine backend only; the
    constraint tree has no kernel path.

    Callers normally reach this through `Locale.workload("sort", ...)`
    (`repro.core.api`), which supplies (mesh, axis, policy) from one object.
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; want one of {BACKENDS}")
    if backend == "shard_map":
        from repro.core.engine import make_engine_fn   # local: avoid cycle
        return make_engine_fn(mesh, policy, num_workers=num_workers,
                              local_sort=local_sort or "bitonic",
                              axis=axis, interpret=interpret,
                              local_phase=local_phase)
    if local_phase not in (None, "reference"):
        raise ValueError(
            f"local_phase={local_phase!r} needs backend='shard_map' — the "
            f"constraint tree's local phase is the jnp reference by nature")
    fn = partial(distributed_merge_sort, mesh=mesh, policy=policy,
                 num_workers=num_workers, local_sort=local_sort or jnp.sort,
                 axis=axis)
    granule = constraint_granule(mesh, policy, num_workers, axis)
    return sort_entry(jax.jit(fn, donate_argnums=(0,)), granule)
