"""Distributed parallel merge sort — the paper's validation application.

Structure mirrors Algorithm 3: a local sort per worker (the
`mergesort_serial` leaves) followed by a log2(N)-level merge reduction tree.
The merge itself is the classic searchsorted rank-merge (log-depth, fully
vectorised — no data-dependent control flow, so it jits cleanly).

The paper's Table 1 axes map to:
  * homing      — input layout: chunk-contiguous vs hash-interleaved
  * localised   — one-shot `localise()` relayout before compute vs leaving
                  every tree level pinned to the hash layout (repeated
                  remote traffic, one all-to-all per level)
  * static      — explicit layout constraints everywhere vs letting the
                  compiler/runtime decide (the Tile-Linux-scheduler analogue)
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.homing import Homing
from repro.core.localisation import LocalisationPolicy

BIG = {jnp.dtype("int32"): jnp.iinfo(jnp.int32).max,
       jnp.dtype("float32"): jnp.inf}

BACKENDS = ("constraint", "shard_map")


def pad_value(dtype):
    """Sort-neutral sentinel: sorts after every real element of `dtype`."""
    dt = jnp.dtype(dtype)
    if dt in BIG:
        return BIG[dt]
    if jnp.issubdtype(dt, jnp.floating):
        return jnp.inf
    return jnp.iinfo(dt).max


def pad_to_multiple(x, m: int):
    """Pad a 1-D array with BIG sentinels up to the next multiple of m.

    Sentinels sort after (or tie with) every real element, so after sorting
    the original multiset occupies the first `len(x)` slots — the caller
    strips them with `out[:len(x)]`.

    Float inputs must be NaN-free when padding occurs: NaN sorts after the
    inf sentinel, so the tail strip would keep a sentinel and silently drop
    the NaN (the searchsorted rank merge is NaN-unsound anyway).
    """
    n = x.shape[0]
    n_pad = (-n) % m
    if n_pad == 0:
        return x
    fill = jnp.full((n_pad,), pad_value(x.dtype), x.dtype)
    return jnp.concatenate([x, fill])


def merge_sorted(a, b):
    """Merge two sorted 1-D arrays (stable, duplicate-safe rank merge)."""
    na, nb = a.shape[-1], b.shape[-1]
    ia = jnp.arange(na) + jnp.searchsorted(b, a, side="left")
    ib = jnp.arange(nb) + jnp.searchsorted(a, b, side="right")
    out = jnp.zeros(a.shape[:-1] + (na + nb,), a.dtype)
    out = out.at[..., ia].set(a)
    out = out.at[..., ib].set(b)
    return out


_merge_rows = jax.vmap(merge_sorted)


def _constrain_runs(runs, mesh: Optional[Mesh], policy: LocalisationPolicy,
                    axis: str = "data"):
    """Layout the (count, size) run matrix per policy, between tree levels."""
    if mesh is None or not policy.static_mapping:
        return runs
    N = mesh.shape[axis]
    count, size = runs.shape
    if not policy.localised and policy.homing == Homing.LOCAL_CHUNKED:
        # paper case 2/4: the conventional code under local homing — the whole
        # array is homed where it was created (one tile), every worker reads
        # remotely. Pod analogue: full replication (broadcast per level).
        return jax.lax.with_sharding_constraint(
            runs, NamedSharding(mesh, P(None, None)))
    if policy.localised:
        # each run homed on its leader's device (chunk-contiguous rows)
        spec = P(axis, None) if count % N == 0 else P(None, axis) \
            if size % N == 0 else P(None, None)
        return jax.lax.with_sharding_constraint(runs, NamedSharding(mesh, spec))
    # hash-for-home: every run striped element-wise across all devices
    if size % N == 0:
        r = runs.reshape(count, size // N, N)
        r = jax.lax.with_sharding_constraint(
            r, NamedSharding(mesh, P(None, None, axis)))
        return r.reshape(count, size)
    return runs


def distributed_merge_sort(x, mesh: Optional[Mesh] = None,
                           policy: LocalisationPolicy = LocalisationPolicy(),
                           num_workers: Optional[int] = None,
                           local_sort: Callable = jnp.sort,
                           axis: str = "data"):
    """Sort a 1-D array with an m-worker merge tree (m = #devices default).

    Arbitrary lengths are supported: the input is padded with BIG sentinels
    up to the next multiple of m and the padding is stripped after the tree.
    Float inputs must be NaN-free (see `pad_to_multiple`).
    """
    n = x.shape[0]
    m = num_workers or (mesh.shape[axis] if mesh is not None else 8)
    assert (m & (m - 1)) == 0, m

    x = pad_to_multiple(x, m)
    runs = x.reshape(m, x.shape[0] // m)
    runs = _constrain_runs(runs, mesh, policy, axis)
    runs = local_sort(runs, axis=-1)                 # leaves of the tree
    runs = _constrain_runs(runs, mesh, policy, axis)
    while runs.shape[0] > 1:
        merged = _merge_rows(runs[0::2], runs[1::2])
        runs = _constrain_runs(merged, mesh, policy, axis)
    return runs[0][:n]


def make_sort_fn(mesh, policy: LocalisationPolicy, num_workers=None,
                 local_sort=None, backend: str = "constraint",
                 axis: str = "data", interpret: bool = True):
    """Jitted sort for one Table-1 case; input buffer donated (step 5).

    backend="constraint": the original `with_sharding_constraint`-hint tree —
    layout is *suggested* and the XLA SPMD partitioner picks the collectives.
    backend="shard_map": the explicit per-device execution engine
    (`repro.core.engine`) — ownership, local Pallas sort and inter-device
    exchange are spelled out literally (paper Algorithms 1-3).

    `local_sort=None` picks the backend default (jnp.sort for the hint
    backend, the Pallas bitonic kernel for the engine).

    Callers normally reach this through `Locale.workload("sort", ...)`
    (`repro.core.api`), which supplies (mesh, axis, policy) from one object.
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; want one of {BACKENDS}")
    if backend == "shard_map":
        from repro.core.engine import make_engine_fn   # local: avoid cycle
        return make_engine_fn(mesh, policy, num_workers=num_workers,
                              local_sort=local_sort or "bitonic",
                              axis=axis, interpret=interpret)
    fn = partial(distributed_merge_sort, mesh=mesh, policy=policy,
                 num_workers=num_workers, local_sort=local_sort or jnp.sort,
                 axis=axis)
    return jax.jit(fn, donate_argnums=(0,))
