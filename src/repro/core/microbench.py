"""The paper's Fig-1 micro-benchmark: repetitive array passes, localised vs not.

Each of m workers owns chunk w of the input and performs R elementwise
passes over it, writing its output chunk. Under *local homing* + localisation
the chunk is copied to the worker's device once and every pass is local.
Under *hash-for-home*, every pass reads an element-interleaved (remote)
layout and writes the worker-owned chunk — one all-to-all per pass.

The wall-clock gap therefore grows with R: the one-shot localisation copy is
amortised, exactly the paper's Figure 1.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core.homing import Homing, constrain
from repro.core.localisation import LocalisationPolicy, localise


def _pass(y):
    return y * 1.0001 + 1.0   # elementwise 'copy with work' (defeats DCE)


def repetitive_copy(x, reps: int, mesh: Optional[Mesh],
                    policy: LocalisationPolicy):
    """R passes over a 1-D array under the policy. Returns the output array."""
    if policy.localised:
        y = localise(x, mesh)               # Algorithm 2's memcpy, once

        def body(_, y):
            return localise(_pass(y), mesh)  # stays local: no traffic
    else:
        y = x

        def body(_, y):
            if mesh is not None and policy.static_mapping:
                y = constrain(y, mesh, policy.homing)   # re-pin to hash layout
            z = _pass(y)
            return localise(z, mesh)        # worker writes its own chunk
    y = jax.lax.fori_loop(0, reps, body, y)
    return localise(y, mesh)


def reference(x, reps: int):
    """Pure-jnp oracle (single device)."""
    y = x
    for _ in range(reps):
        y = _pass(y)
    return y


def make_microbench_fn(mesh, policy: LocalisationPolicy, reps: int):
    return jax.jit(partial(repetitive_copy, reps=reps, mesh=mesh,
                           policy=policy), donate_argnums=(0,))
