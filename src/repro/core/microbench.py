"""The paper's Fig-1 micro-benchmark: repetitive array passes, localised vs not.

Each of m workers owns chunk w of the input and performs R elementwise
passes over it, writing its output chunk. Under *local homing* + localisation
the chunk is copied to the worker's device once and every pass is local.
Under *hash-for-home*, every pass reads an element-interleaved (remote)
layout and writes the worker-owned chunk — one all-to-all per pass.

The wall-clock gap therefore grows with R: the one-shot localisation copy is
amortised, exactly the paper's Figure 1.

Entry point: `Locale.workload("microbench", reps=R)` (`repro.core.api`).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core.homing import Axis, Homing, constrain
from repro.core.localisation import LocalisationPolicy, localise


def _pass(y):
    return y * 1.0001 + 1.0   # elementwise 'copy with work' (defeats DCE)


def repetitive_copy(x, reps: int, mesh: Optional[Mesh],
                    policy: LocalisationPolicy, axis: Axis = "data"):
    """R passes over a 1-D array under the policy. Returns the output array."""
    static = mesh is not None and policy.static_mapping
    if policy.localised:
        y = localise(x, mesh, axis)          # Algorithm 2's memcpy, once

        def body(_, y):
            return localise(_pass(y), mesh, axis)  # stays local: no traffic
    elif static:
        y = x

        def body(_, y):
            y = constrain(y, mesh, policy.homing, axis)  # re-pin to hash layout
            z = _pass(y)
            return localise(z, mesh, axis)   # worker writes its own chunk
    else:
        # the 'leave it to the compiler/scheduler' baseline: no constraints
        # at all — any layout hint here would silently un-baseline it
        y = x

        def body(_, y):
            return _pass(y)
    y = jax.lax.fori_loop(0, reps, body, y)
    return localise(y, mesh, axis) if (policy.localised or static) else y


def reference(x, reps: int):
    """Pure-jnp oracle (single device)."""
    y = x
    for _ in range(reps):
        y = _pass(y)
    return y


def make_microbench_fn(mesh, policy: LocalisationPolicy, reps: int,
                       axis: Axis = "data"):
    return jax.jit(partial(repetitive_copy, reps=reps, mesh=mesh,
                           policy=policy, axis=axis), donate_argnums=(0,))
