"""The paper's technique, generalised: Algorithm 1 for device meshes.

  1. Divide the input array of size n into m = #devices chunks.     (bounds)
  2. Assign each chunk to a worker by passing pointers.              (specs)
  3. Map each worker to a core — STATIC.                             (mesh order)
  4. Copy each part into a locally-homed buffer.                     (localise)
  5. Free the dynamic memory as soon as possible.                    (donation)

`localise` is the memcpy of Algorithm 2: a one-shot relayout into the
chunk-contiguous ("locally homed") layout, done *before* repeated-access
compute. Its cost is one all-to-all; it pays for itself once the data is
touched more than ~once — exactly the paper's Fig 1 amortisation argument.

These are the policy mechanics behind the public `repro.core.api` surface:
`Locale.localise` / `Locale.pin` / `Locale.jit` wrap them with the
(mesh, axis, policy) bundle so callers never thread those tuples by hand.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.homing import Homing, chunked_sharding, constrain


def chunk_bounds(n: int, m: int) -> Tuple[Tuple[int, int], ...]:
    """Ownership math: chunk w = [w*ceil(n/m), ...) clipped (paper step 1)."""
    c = -(-n // m)
    return tuple((min(w * c, n), min((w + 1) * c, n)) for w in range(m))


@dataclass(frozen=True)
class LocalisationPolicy:
    """The three building blocks, as independently switchable knobs."""
    localised: bool = True        # copy chunks into locally-homed buffers
    static_mapping: bool = True   # explicit layouts vs compiler-chosen
    homing: Homing = Homing.LOCAL_CHUNKED

    @property
    def name(self) -> str:
        return (f"{'loc' if self.localised else 'nonloc'}-"
                f"{'static' if self.static_mapping else 'auto'}-"
                f"{self.homing.value}")


def localise(x, mesh: Optional[Mesh], axis: str = "data"):
    """One-shot reshard into the chunk-contiguous locally-homed layout."""
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, chunked_sharding(mesh, axis))


def place(x, mesh: Optional[Mesh], policy: LocalisationPolicy,
          axis: str = "data"):
    """Layout an intermediate value according to the policy (inside jit).

    - static+localised: chunk-contiguous (the technique).
    - static+non-localised: pinned to the input's homing (repeated remote
      access under hash-for-home — the conventional style on Tile Linux).
    - non-static: no constraint; the compiler/runtime chooses (the
      'leave it to the OS scheduler' baseline).
    """
    if mesh is None or not policy.static_mapping:
        return x
    if policy.localised:
        return localise(x, mesh, axis)
    return constrain(x, mesh, policy.homing, axis)


def donate_buffers(fn):
    """Paper step 5 ('free as soon as finished') == buffer donation."""
    return jax.jit(fn, donate_argnums=(0,))
