"""The paper's technique, generalised: Algorithm 1 for device meshes.

  1. Divide the input array of size n into m = #devices chunks.     (bounds)
  2. Assign each chunk to a worker by passing pointers.              (specs)
  3. Map each worker to a core — STATIC.                             (mesh order)
  4. Copy each part into a locally-homed buffer.                     (localise)
  5. Free the dynamic memory as soon as possible.                    (donation)

`localise` is the memcpy of Algorithm 2: a one-shot relayout into the
chunk-contiguous ("locally homed") layout, done *before* repeated-access
compute. Its cost is one all-to-all; it pays for itself once the data is
touched more than ~once — exactly the paper's Fig 1 amortisation argument.

These are the policy mechanics behind the public `repro.core.api` surface:
`Locale.localise` / `Locale.pin` / `Locale.jit` wrap them with the
(mesh, axis, policy) bundle so callers never thread those tuples by hand.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.homing import Axis, Homing, chunked_sharding, constrain


def chunk_bounds(n: int, m: int) -> Tuple[Tuple[int, int], ...]:
    """Ownership math: chunk w = [w*ceil(n/m), ...) clipped (paper step 1)."""
    c = -(-n // m)
    return tuple((min(w * c, n), min((w + 1) * c, n)) for w in range(m))


@dataclass(frozen=True)
class LocalisationPolicy:
    """The three building blocks, as independently switchable knobs.

    `outer` is the DCN-aware fourth knob for hierarchical (pod, data) meshes:
    ``None`` treats the sort axes as one flat device space; ``"hash"`` /
    ``"replicate"`` confine the deep merge-split levels to intra-pod
    neighbour exchanges and run each top (cross-pod) merge level as a single
    ``all_gather`` over the pod axis, with the cross-pod merge-split work
    replicated per pod — data ownership never migrates across the slow link.
    """
    localised: bool = True        # copy chunks into locally-homed buffers
    static_mapping: bool = True   # explicit layouts vs compiler-chosen
    homing: Homing = Homing.LOCAL_CHUNKED
    outer: Optional[str] = None   # None = flat; "hash" | "replicate"

    OUTER_MODES = (None, "hash", "replicate")

    def __post_init__(self):
        if self.outer not in self.OUTER_MODES:
            raise ValueError(f"unknown outer mode {self.outer!r}; "
                             f"want one of {self.OUTER_MODES}")
        if self.outer is not None and not self.localised:
            raise ValueError(
                "outer={!r} needs localised=True — the hierarchical engine "
                "is the localised path's merge-split network; non-localised "
                "gathers everything every level regardless".format(self.outer))

    @classmethod
    def hierarchical(cls, inner: str = "localised",
                     outer: str = "hash") -> "LocalisationPolicy":
        """The two-distance-class policy for (pod, data) meshes.

        ``inner`` is the intra-pod discipline: ``"localised"`` starts from
        chunk-contiguous input (each pod owns a contiguous segment, each
        device its chunk — no relayout), ``"hash"`` starts element-interleaved
        across all devices and pays the one-shot all_to_all relayout first.
        ``outer`` picks how the top log2(n_pods) merge levels cross pods
        (see the class docstring); both modes currently share the
        gather-and-replicate engine path.
        """
        if inner not in ("localised", "hash"):
            raise ValueError(f"unknown inner mode {inner!r}; "
                             f"want 'localised' or 'hash'")
        homing = (Homing.LOCAL_CHUNKED if inner == "localised"
                  else Homing.HASH_INTERLEAVED)
        return cls(localised=True, static_mapping=True, homing=homing,
                   outer=outer)

    @property
    def name(self) -> str:
        hier = f"hier.{self.outer}-" if self.outer else ""
        return (f"{hier}{'loc' if self.localised else 'nonloc'}-"
                f"{'static' if self.static_mapping else 'auto'}-"
                f"{self.homing.value}")


def localise(x, mesh: Optional[Mesh], axis: Axis = "data"):
    """One-shot reshard into the chunk-contiguous locally-homed layout."""
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, chunked_sharding(mesh, axis))


def place(x, mesh: Optional[Mesh], policy: LocalisationPolicy,
          axis: Axis = "data"):
    """Layout an intermediate value according to the policy (inside jit).

    - static+localised: chunk-contiguous (the technique).
    - static+non-localised: pinned to the input's homing (repeated remote
      access under hash-for-home — the conventional style on Tile Linux).
    - non-static: no constraint; the compiler/runtime chooses (the
      'leave it to the OS scheduler' baseline).
    """
    if mesh is None or not policy.static_mapping:
        return x
    if policy.localised:
        return localise(x, mesh, axis)
    return constrain(x, mesh, policy.homing, axis)


def donate_buffers(fn):
    """Paper step 5 ('free as soon as finished') == buffer donation."""
    return jax.jit(fn, donate_argnums=(0,))
