"""Explicit `shard_map` execution engine for the distributed merge sort.

The constraint backend (`core/sort.py`, backend="constraint") only *hints*
layouts with `with_sharding_constraint` and leaves collective choice to the
XLA SPMD partitioner — exactly the "leave it to the scheduler" baseline the
paper argues against.  This engine instead implements Algorithms 1-3
literally, per device:

  1. chunk ownership comes from `chunk_bounds` (paper step 1/2) — after BIG
     padding every device owns one equal, contiguous logical chunk;
  2. the worker->core map is the mesh order, fixed at trace time (step 3 —
     the engine *is* the static mapping; `policy.static_mapping` has no
     runtime-chosen analogue here and is ignored);
  3. the per-device local sort runs the Pallas `bitonic_sort` kernel inside
     each shard — the VMEM-resident `input_cpy` of Algorithm 2;
  4. the log2(m)-level merge tree exchanges runs with *explicit* collectives
     chosen by `LocalisationPolicy`:

       localised      — one-shot relayout into the locally-homed chunk
                        layout (`lax.all_to_all` when the input is
                        hash-interleaved, free when chunk-contiguous), then a
                        block-wise bitonic merge-split network: log2(m)
                        stages, stage i making i+1 pairwise chunk exchanges
                        with device d XOR 2^j via `lax.ppermute` —
                        neighbour-only traffic, O(n/m) memory per device,
                        data never re-homed.
       non-localised  — intermediate runs stay pinned to the *input* homing
                        between levels, so every level re-reads the whole
                        array remotely (`lax.all_gather`, the full exchange
                        the paper charges to hash-for-home), merges, and
                        scatters its own home shard back.  Under
                        hash-interleaving every element of a worker's run
                        lives on another device — the per-level all-to-all
                        of Table 1 cases 1/3.

Two distance classes (the NUCA gradient of a multi-pod deployment — fast
ICI within a pod, slow DCN across pods) enter through `axis`: a *tuple* of
mesh axes, outer (pod) axes first, linearised row-major so device
d = pod * n_inner + inner owns logical chunk d.  Merge-split strides that
stay below the inner-axis size toggle only the inner index — those
exchanges run as intra-pod `ppermute`s on the fast axis.  Strides at or
above it toggle only pod bits; how they cross the slow link is the
policy's `outer` knob:

  outer=None          — flat: cross-pod substages are the same pairwise
                        chunk `ppermute`s, just routed over the pod axis
                        (stride-many DCN round trips per top stage).
  outer="hash"/
  "replicate"         — hierarchical: each top stage's cross-pod substages
                        collapse into ONE `all_gather` over the pod axes
                        (the n_pods chunks at my inner index), and every
                        pod replays the stage's cross-pod merge-splits
                        locally on the gathered copies — one DCN collective
                        per top level, merge work replicated, ownership
                        never migrating across pods.  Only the top
                        log2(n_pods) levels touch DCN at all.

The engine returns the same logical sorted array as `jnp.sort`, placed
chunk-contiguous when localised and in the input homing otherwise.

The *local* half of each device's work — the leaf sorts, the local merge
tree and the merge-split of every network substage — has two
implementations, selected by ``local_phase``:

  "pallas"     — the VMEM-resident production path: `kernels.local_sort`
                 fuses the leaf sorts and the whole local merge tree into
                 ONE pallas_call (chunk read from HBM once, written once),
                 and `kernels.merge_split` computes only the *kept* half of
                 every compare-exchange (merge-path partitioning: C outputs
                 from 2C inputs, never materialising the discarded half).
  "reference"  — the jnp oracle: per-leaf Pallas sort, then a Python loop
                 of HBM-materialising vmapped rank merges, and
                 merge-everything-discard-half at every network substage.

``local_phase=None`` auto-selects: "pallas" for the default
``local_sort="bitonic"``, "reference" when a callable leaf sort is given
(a callable can't be fused into the kernel).  The non-localised path's
merge levels are interleaved with all_gathers, so only its leaf sort is a
kernel; its merge tree is always the reference form.
"""
from __future__ import annotations

import functools
import itertools
import math
from dataclasses import dataclass
from functools import partial
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.core.homing import Axis, Homing, axis_tuple
from repro.core.localisation import LocalisationPolicy, chunk_bounds
from repro.core.sort import (check_pad_outside_trace, merge_sorted,
                             pad_to_multiple)
from repro.kernels.local_sort import local_sort as _local_sort_kernel
from repro.kernels.merge_split import merge_split as _merge_split_kernel
from repro.obs.tracelog import get_tracer

#: engine.sort span ids — groups a span's engine.exchange_level events
_SORT_CALLS = itertools.count(1)

AXIS = "data"

_merge_rows = jax.vmap(merge_sorted)

LocalSort = Union[str, Callable]

LOCAL_PHASES = ("pallas", "reference")


def resolve_local_phase(local_phase: Optional[str],
                        local_sort: LocalSort) -> str:
    """The ``local_phase`` contract, shared by the engine and the schedule.

    None auto-selects: "pallas" (the fused-kernel production path) when the
    leaf sort is the default "bitonic", "reference" when a callable leaf
    sort was supplied — an arbitrary callable cannot run inside the fused
    kernel, so it implies the jnp oracle path.
    """
    if local_phase is None:
        return "pallas" if local_sort == "bitonic" else "reference"
    if local_phase not in LOCAL_PHASES:
        raise ValueError(f"unknown local_phase {local_phase!r}; "
                         f"want one of {LOCAL_PHASES} (or None = auto)")
    if local_phase == "pallas" and callable(local_sort):
        raise ValueError(
            "local_phase='pallas' runs the whole local phase inside the "
            "fused Pallas kernels; a callable local_sort only applies to "
            "local_phase='reference'")
    return local_phase


def _axes_sizes(mesh: Mesh, axes: Tuple[str, ...]) -> Tuple[int, ...]:
    sizes = tuple(mesh.shape[a] for a in axes)
    for a, s in zip(axes, sizes):
        assert (s & (s - 1)) == 0, f"axis {a!r} size {s} not a power of 2"
    return sizes


def _axis_name(axes: Tuple[str, ...]):
    """The collective axis-name argument: bare name or tuple (linearised)."""
    return axes[0] if len(axes) == 1 else axes


def engine_granule(m: int, num_workers: Optional[int],
                   hash_homed: bool) -> int:
    """The engine's padding granule: the chunk must split into per-device
    leaves, and (when relaying out of the interleaved homing) into one
    all-to-all block per peer device.  The one definition shared by
    `shard_map_sort` (in-trace no-op re-pad), `make_engine_fn` (the eager
    pad that must match it) and `exchange_schedule` (the byte model)."""
    w = num_workers or m
    assert w % m == 0 and (w & (w - 1)) == 0, (w, m)
    return m * math.lcm(w // m, m if hash_homed else 1)


def _stride_axis(axes: Tuple[str, ...], sizes: Tuple[int, ...],
                 j: int) -> Tuple[str, int]:
    """Which mesh axis bit j of the linearised device index lives on.

    Row-major linearisation with power-of-two sizes means stride 2^j over
    the combined index toggles exactly one bit of exactly one axis's local
    index: returns (axis_name, local_stride).
    """
    bit = j
    for a, s in zip(reversed(axes), reversed(sizes)):
        la = s.bit_length() - 1
        if bit < la:
            return a, 1 << bit
        bit -= la
    raise ValueError(f"stride 2^{j} exceeds the {math.prod(sizes)}-device space")


def _leaf_sort(rows, local_sort: LocalSort, interpret: bool):
    """Sort each leaf row. rows: (k, leaf) -> (k, leaf) row-sorted.

    local_sort="bitonic" runs one kernel grid step per leaf, entirely in
    VMEM; non-power-of-two leaves are sentinel-padded *inside* the kernel's
    VMEM scratch (`kernels.local_sort`), so no padded copy ever touches HBM
    — the old path concatenated up to 2x sentinel tail per call.  A callable
    is applied as `local_sort(rows, axis=-1)`.
    """
    if callable(local_sort):
        return local_sort(rows, axis=-1)
    if local_sort != "bitonic":
        raise ValueError(f"unknown local_sort {local_sort!r}")
    return _local_sort_kernel(rows, interpret=interpret)


def _merge_split(run, other, chunk: int, keep_low):
    """One compare-exchange of the block bitonic network: merge, keep half.

    The reference form: merges the full 2*chunk run and discards half — 2x
    the merge compute and HBM traffic of the kept result.  The "pallas"
    local phase replaces it with `kernels.merge_split`, which computes only
    the kept half (bit-exact, same rank arithmetic).
    """
    both = merge_sorted(run, other)                  # (2*chunk,)
    return jnp.where(keep_low, both[:chunk], both[chunk:])


# ---------------------------------------------------------------------------
# the exchange network, as data
# ---------------------------------------------------------------------------
#
# The merge-split network's structure — which device exchanges with which,
# over which mesh axis, keeping which half — used to live only inside the
# traced `_localised_shard` loop, where nothing could inspect it.  It is now
# built once as a plain descriptor (`exchange_network`) that BOTH the runtime
# (the shard_map body below iterates it) and the static analyzer
# (`repro.analysis.netverify`, rule R6) consume, so "the schedule the engine
# runs" and "the schedule the analyzer certifies" cannot drift apart.

@dataclass(frozen=True)
class NetExchange:
    """One pairwise compare-exchange substage: a ppermute + merge-split.

    `partner`/`keep_low` are the device-space view over all m linearised
    devices (partner[d] = d XOR 2^substage; keep_low[d] = low-half iff the
    bitonic direction bit says so); `axis`/`axis_stride`/`perm` are the
    on-axis routing the runtime hands to `lax.ppermute`.
    """
    stage: int                      # merge stage i (sorts runs of 2^(i+1))
    substage: int                   # j: global device-index bit toggled
    axis: str                       # mesh axis the ppermute runs over
    axis_stride: int                # stride on that axis's local index
    stride: int                     # global linearised stride == 2^substage
    perm: Tuple[Tuple[int, int], ...]   # on-axis (src, dst) pairs
    partner: Tuple[int, ...]        # device-space partner map (involution)
    keep_low: Tuple[bool, ...]      # device-space keep flag


@dataclass(frozen=True)
class NetReplay:
    """One cross-pod substage replayed locally per pod (hierarchical path).

    `pod_partner`/`pod_keep_low` index pod space (what the replay loop
    actually uses on the gathered rows); `partner`/`keep_low` are the
    equivalent device-space view — identical formulas to `NetExchange`,
    because toggling pod bit (substage - log_inner) of q toggles exactly
    bit `substage` of d = q * m_inner + inner.
    """
    stage: int
    substage: int
    stride: int                     # global stride == 2^substage >= m_inner
    pod_partner: Tuple[int, ...]
    pod_keep_low: Tuple[bool, ...]
    partner: Tuple[int, ...]
    keep_low: Tuple[bool, ...]


@dataclass(frozen=True)
class NetGatherReplay:
    """One hierarchical top stage: ONE all_gather over the pod axes, then
    the stage's cross-pod substages replayed per pod on the gathered rows,
    each device finally keeping its own pod's chunk."""
    stage: int
    axes: Union[str, Tuple[str, ...]]   # outer (pod) axes gathered over
    replays: Tuple[NetReplay, ...]


@dataclass(frozen=True)
class ExchangeNetwork:
    """The localised engine's full exchange plan for one (policy, mesh).

    `levels` holds `NetExchange` / `NetGatherReplay` entries in execution
    order; `substages()` flattens to the device-space compare-exchange
    sequence (the thing the 0-1 principle certifies).  `relayout` records
    whether the plan starts with the hash-homing all_to_all.
    """
    axes: Tuple[str, ...]
    sizes: Tuple[int, ...]
    m: int
    hier: bool
    relayout: bool
    levels: Tuple[Union[NetExchange, NetGatherReplay], ...]

    def substages(self):
        """Device-space compare-exchanges (NetExchange | NetReplay), in order."""
        for lv in self.levels:
            if isinstance(lv, NetGatherReplay):
                for rp in lv.replays:
                    yield rp
            else:
                yield lv


def _keep_low(m: int, i: int, j: int) -> np.ndarray:
    """Bitonic keep flags over device space: device d keeps the low half of
    the merged pair iff its low/high role (bit j) matches the run's
    direction (bit i+1)."""
    d = np.arange(m)
    ascending = ((d >> (i + 1)) & 1) == 0
    is_low = ((d >> j) & 1) == 0
    return is_low == ascending


def exchange_network(policy: LocalisationPolicy, sizes: Sequence[int],
                     axes: Optional[Sequence[str]] = None) -> ExchangeNetwork:
    """The merge-split network descriptor for one (policy, mesh-slice).

    `sizes` are the sort-axis sizes in axis order, inner (ICI) last —
    the same contract as `exchange_schedule`; `axes` the matching mesh axis
    names (synthesised as ax0.. when only the shape matters, e.g. for
    certification).  Raises ValueError for non-localised policies (their
    all_gather levels have no merge-split network to describe) and for a
    hierarchical policy on a single-axis shape — identical validation to
    `shard_map_sort`, so a descriptor exists exactly when the engine would
    run the network.
    """
    sizes = tuple(int(s) for s in sizes)
    if axes is None:
        axes = tuple(f"ax{k}" for k in range(len(sizes)))
    axes = tuple(axes)
    if len(axes) != len(sizes):
        raise ValueError(f"axes {axes!r} do not match sizes {sizes!r}")
    for a, s in zip(axes, sizes):
        if s < 1 or (s & (s - 1)) != 0:
            raise ValueError(f"axis {a!r} size {s} not a power of 2")
    if not policy.localised:
        raise ValueError(
            f"policy {policy.name!r} is non-localised: every level is an "
            f"all_gather full exchange — there is no merge-split network")
    hier = policy.outer is not None
    if hier and len(sizes) < 2:
        raise ValueError(
            f"hierarchical policy {policy.name!r} needs (pod, ..., inner) "
            f"axis sizes, got {sizes!r} — same contract as shard_map_sort")
    m = math.prod(sizes)
    m_inner = sizes[-1]
    log_inner = m_inner.bit_length() - 1
    n_pods = m // m_inner
    d = np.arange(m)
    levels: List[Union[NetExchange, NetGatherReplay]] = []
    for i in range(m.bit_length() - 1):
        j0 = i
        if hier and i >= log_inner:
            q = np.arange(n_pods)
            replays = []
            for j in range(i, log_inner - 1, -1):
                t = 1 << (j - log_inner)            # pod-index stride
                pod_keep = ((((q >> (j - log_inner)) & 1) == 0)
                            == (((q >> (i + 1 - log_inner)) & 1) == 0))
                replays.append(NetReplay(
                    stage=i, substage=j, stride=1 << j,
                    pod_partner=tuple(int(p) for p in q ^ t),
                    pod_keep_low=tuple(bool(b) for b in pod_keep),
                    partner=tuple(int(p) for p in d ^ (1 << j)),
                    keep_low=tuple(bool(b) for b in _keep_low(m, i, j))))
            levels.append(NetGatherReplay(
                stage=i, axes=_axis_name(axes[:-1]), replays=tuple(replays)))
            j0 = log_inner - 1
        for j in range(j0, -1, -1):
            ax, t = _stride_axis(axes, sizes, j)
            na = sizes[axes.index(ax)]
            levels.append(NetExchange(
                stage=i, substage=j, axis=ax, axis_stride=t, stride=1 << j,
                perm=tuple((a, a ^ t) for a in range(na)),
                partner=tuple(int(p) for p in d ^ (1 << j)),
                keep_low=tuple(bool(b) for b in _keep_low(m, i, j))))
    return ExchangeNetwork(
        axes=axes, sizes=sizes, m=m, hier=hier,
        relayout=policy.homing == Homing.HASH_INTERLEAVED,
        levels=tuple(levels))


def _localised_shard(xloc, *, m: int, chunk: int, w_per_dev: int,
                     hash_homed: bool, local_sort: LocalSort, interpret: bool,
                     axes: Tuple[str, ...], sizes: Tuple[int, ...],
                     net: "ExchangeNetwork", local_phase: str):
    """Per-device body, localised: one-shot relayout + merge-split tree."""
    name = _axis_name(axes)
    if hash_homed:
        # Algorithm 2's memcpy: one explicit all-to-all turns my interleaved
        # column into my contiguous chunk (order scrambled; the sort fixes it).
        blocks = xloc.reshape(m, chunk // m)     # block j goes to device j
        mine = jax.lax.all_to_all(blocks, name, 0, 0).reshape(-1)
    else:
        mine = xloc                       # already the locally-homed chunk
    if local_phase == "pallas":
        # Algorithm 2 for the whole local phase: ONE pallas_call copies my
        # chunk into VMEM, runs the leaf stages AND the full local merge
        # tree on-chip, and writes the sorted run back once.
        run = _local_sort_kernel(mine.reshape(1, chunk),
                                 interpret=interpret)[0]
    else:
        runs = _leaf_sort(mine.reshape(w_per_dev, chunk // w_per_dev),
                          local_sort, interpret)
        while runs.shape[0] > 1:          # merge my own leaves, no traffic
            runs = _merge_rows(runs[0::2], runs[1::2])
        run = runs[0]
    # block-wise bitonic merge-split network over the hypercube: stage i
    # sorts runs of 2^(i+1) blocks; each substage swaps the full chunk with
    # device d XOR 2^j, merges, and keeps the low or high half.  Per-device
    # memory stays at chunk size — no device ever materialises more than a
    # pod's worth of chunks — and the sorted array ends naturally distributed
    # in ownership order (compare-exchange -> merge-split block sorting is
    # exact by the 0-1 principle, given sorted blocks).  The structure —
    # who exchanges with whom, keeping which half — comes from the
    # `exchange_network` descriptor, the same object `repro.analysis`'s
    # rule R6 certifies; the loop below only routes it.
    d = jax.lax.axis_index(name)          # linearised (pod-major) device id
    m_inner = sizes[-1]
    log_inner = m_inner.bit_length() - 1
    for lv in net.levels:
        if isinstance(lv, NetGatherReplay):
            # hierarchical top level: ONE all_gather over the pod axes pulls
            # the n_pods chunks at my inner index; this stage's cross-pod
            # substages (they toggle only pod bits, so everything they read
            # sits in the gathered set) are replayed locally for every pod,
            # then I keep my own pod's chunk.  One DCN collective replaces
            # (stage - log_inner + 1) pairwise DCN hops.
            pods = jax.lax.all_gather(run, lv.axes, axis=0)  # (n_pods, chunk)
            for rp in lv.replays:
                partner = pods[np.asarray(rp.pod_partner)]
                keep_low = jnp.asarray(np.asarray(rp.pod_keep_low))
                if local_phase == "pallas":
                    # batched merge-path replay: row q keeps only its half
                    pods = _merge_split_kernel(pods, partner, keep_low,
                                               interpret=interpret)
                else:
                    merged = _merge_rows(pods, partner)  # (n_pods, 2*chunk)
                    pods = jnp.where(keep_low[:, None], merged[:, :chunk],
                                     merged[:, chunk:])
            run = jnp.take(pods, d >> log_inner, axis=0)
        else:
            other = jax.lax.ppermute(run, lv.axis, list(lv.perm))
            keep_low = jnp.asarray(np.asarray(lv.keep_low))[d]
            if local_phase == "pallas":
                run = _merge_split_kernel(run[None], other[None], keep_low,
                                          interpret=interpret)[0]
            else:
                run = _merge_split(run, other, chunk, keep_low)
    return run


def _unlocalised_shard(xloc, *, m: int, chunk: int, w: int,
                       hash_homed: bool, local_sort: LocalSort,
                       interpret: bool, axes: Tuple[str, ...]):
    """Per-device body, non-localised: runs stay home-pinned between levels.

    Every level gathers the whole array (each worker's reads are remote —
    under hash homing literally every element comes from another device),
    does the level's merges, and writes back only its own home shard.  The
    merge work is replicated across devices: without ownership there is no
    cheap way to partition it, which is the paper's point.  On a pod mesh
    every one of these gathers is a full cross-pod exchange — the DCN bill
    the hierarchical policy exists to avoid.
    """
    name = _axis_name(axes)
    d = jax.lax.axis_index(name)

    if hash_homed:
        def gather(col):                          # (chunk, 1) -> (n_p,)
            full = jax.lax.all_gather(col, name, axis=1, tiled=True)
            return full.reshape(-1)

        def scatter(full):                        # (n_p,) -> (chunk, 1)
            return jax.lax.dynamic_slice(
                full.reshape(chunk, m), (0, d), (chunk, 1))
    else:
        def gather(blk):                          # (chunk,) -> (n_p,)
            return jax.lax.all_gather(blk, name, axis=0, tiled=True)

        def scatter(full):                        # (n_p,) -> (chunk,)
            return jax.lax.dynamic_slice(full, (d * chunk,), (chunk,))

    n_p = chunk * m
    full = gather(xloc)                           # leaves: remote read
    runs = _leaf_sort(full.reshape(w, n_p // w), local_sort, interpret)
    xloc = scatter(runs.reshape(-1))
    for _ in range(w.bit_length() - 1):
        full = gather(xloc)                       # per-level full exchange
        runs = full.reshape(runs.shape[0], -1)
        runs = _merge_rows(runs[0::2], runs[1::2])
        xloc = scatter(runs.reshape(-1))
    return xloc


def shard_map_sort(x, mesh: Mesh,
                   policy: LocalisationPolicy = LocalisationPolicy(),
                   num_workers: Optional[int] = None,
                   local_sort: LocalSort = "bitonic",
                   interpret: bool = True, axis: Axis = AXIS,
                   local_phase: Optional[str] = None):
    """Sort a 1-D array with the explicit shard_map engine (traceable).

    ``local_phase`` selects the per-device compute implementation (see the
    module docstring): "pallas" = fused VMEM-resident kernels, "reference" =
    the jnp oracle path, None = auto by ``local_sort``.
    """
    local_phase = resolve_local_phase(local_phase, local_sort)
    axes = axis_tuple(axis)
    sizes = _axes_sizes(mesh, axes)
    n = x.shape[0]
    m = math.prod(sizes)
    w = num_workers or m
    w_per_dev = w // m
    hash_homed = policy.homing == Homing.HASH_INTERLEAVED
    hier = policy.outer is not None
    if hier and len(axes) < 2:
        raise ValueError(
            f"hierarchical policy {policy.name!r} needs a (pod, ..., inner) "
            f"axis tuple, got {axis!r} — use a flat policy on one axis")

    granule = engine_granule(m, num_workers, hash_homed)
    check_pad_outside_trace(n, granule, mesh, axes, "shard_map_sort")
    x = pad_to_multiple(x, granule)
    n_p = x.shape[0]
    bounds = chunk_bounds(n_p, m)                  # ownership, paper step 1
    chunk = bounds[0][1] - bounds[0][0]
    assert all(hi - lo == chunk for lo, hi in bounds)

    spec_axis = axes[0] if len(axes) == 1 else axes   # P entry: name | tuple
    if hash_homed:
        # logical element i*m + d sits in row i of device d's column
        xin = x.reshape(chunk, m)
        in_spec = P(None, spec_axis)
    else:
        xin = x
        in_spec = P(spec_axis)

    if policy.localised:
        body = partial(_localised_shard, m=m, chunk=chunk,
                       w_per_dev=w_per_dev, hash_homed=hash_homed,
                       local_sort=local_sort, interpret=interpret,
                       axes=axes, sizes=sizes,
                       net=exchange_network(policy, sizes, axes),
                       local_phase=local_phase)
        out_spec = P(spec_axis)                    # chunk-contiguous output
    else:
        body = partial(_unlocalised_shard, m=m, chunk=chunk, w=w,
                       hash_homed=hash_homed, local_sort=local_sort,
                       interpret=interpret, axes=axes)
        out_spec = in_spec                         # output stays home-pinned

    y = shard_map(body, mesh=mesh, in_specs=in_spec, out_specs=out_spec,
                  check_rep=False)(xin)
    if y.ndim == 2:                                # interleaved view -> logical
        y = y.reshape(-1)
    return y[:n]


def exchange_schedule(n: int, sizes: Sequence[int],
                      policy: LocalisationPolicy,
                      num_workers: Optional[int] = None,
                      itemsize: int = 4,
                      local_phase: Optional[str] = None) -> List[Dict]:
    """The engine's full execution plan as per-level byte counts (Fig 9).

    `sizes` are the sort-axis sizes in axis order, inner (ICI) last — e.g.
    (2, 4) for a ("pod", "data") mesh slice.  Returns one record per
    collective *and* per local compute step, in execution order.  Every
    record carries ``level`` (0 = relayout/leaves, k = merge level k),
    ``op``, ``inter_pod_bytes`` / ``intra_pod_bytes`` (collective traffic,
    0 for local ops), ``local_hbm_bytes`` (HBM read+write traffic of the
    local compute, 0 for collectives) and ``local_merge_elems`` (merge
    output elements materialised — the "compute only what you keep" count).
    All totals are summed across devices; bytes are hardware-independent
    facts of the schedule, the measurable form of both halves of the
    paper's argument (exchange locality AND cache-resident local phase).

    ``local_phase`` prices the local records ("pallas" = fused one-pass
    kernels + kept-half merge-splits, "reference" = HBM-materialising tree
    + merge-everything-discard-half; None = "pallas", the engine default).
    The collective records are identical under both phases.  Local cost
    model, per device and per step (B = chunk bytes, C = chunk elems,
    T = log2(w_per_dev) local tree levels):

      local_sort   pallas:    2B traffic (one VMEM round trip), C elems
                   reference: 2B*(1+T) traffic (leaves + every tree level
                              re-materialised), C*(1+T) elems
      merge_split  pallas:    3B traffic (read both runs, write kept half),
                              C elems
                   reference: 7B traffic (read 2B, write the 2C merge,
                              re-read it, write the kept half), 2C elems

    Must mirror the shard_map bodies above; the structure tests pin the
    collective records to the lowered HLO's collective counts.
    """
    sizes = tuple(sizes)
    m = math.prod(sizes)
    m_inner = sizes[-1]
    n_pods = m // m_inner
    w = num_workers or m
    hash_homed = policy.homing == Homing.HASH_INTERLEAVED
    hier = policy.outer is not None
    local_phase = resolve_local_phase(local_phase, "bitonic")
    if hier and len(sizes) < 2:
        raise ValueError(
            f"hierarchical policy {policy.name!r} needs (pod, ..., inner) "
            f"axis sizes, got {sizes!r} — same contract as shard_map_sort")
    granule = engine_granule(m, num_workers, hash_homed)
    n_p = n + (-n) % granule
    chunk = n_p // m                                # one chunk, in elements
    B = chunk * itemsize                            # one chunk, in bytes
    log_inner = m_inner.bit_length() - 1
    pallas = local_phase == "pallas"
    out: List[Dict] = []

    def rec(level, op, inter, intra, hbm=0, elems=0):
        out.append({"level": level, "op": op,
                    "inter_pod_bytes": inter, "intra_pod_bytes": intra,
                    "local_hbm_bytes": hbm, "local_merge_elems": elems})

    def merge_split_rec(level, rows):
        """One network substage: every device merge-splits `rows` runs."""
        rec(level, "merge_split", 0, 0,
            hbm=(3 if pallas else 7) * m * rows * B,
            elems=(1 if pallas else 2) * m * rows * chunk)

    if not policy.localised:
        # leaf gather + one full gather per merge level: every device
        # re-reads everything it doesn't hold, at every level.  The local
        # work (each device sorts/merges the whole gathered array) is
        # always the reference tree — its levels are interleaved with the
        # gathers, so there is nothing for the fused kernel to keep
        # resident; ``local_phase`` changes nothing here.
        for lvl in range(w.bit_length()):
            rec(lvl, "all_gather",
                m * (m - m_inner) * B, m * (m_inner - 1) * B)
            rec(lvl, "local_sort" if lvl == 0 else "merge", 0, 0,
                hbm=2 * m * n_p * itemsize, elems=m * n_p)
        return out

    if hash_homed:
        # one-shot relayout: each device sends m-1 of its m chunk-blocks
        rec(0, "all_to_all",
            m * (m - m_inner) * (B // m), m * (m_inner - 1) * (B // m))
    tree = max(0, (w // m).bit_length() - 1)        # local merge-tree levels
    rec(0, "local_sort", 0, 0,
        hbm=2 * n_p * itemsize * (1 if pallas else 1 + tree),
        elems=n_p * (1 if pallas else 1 + tree))
    for i in range(m.bit_length() - 1):
        j0 = i
        if hier and i >= log_inner:
            rec(i + 1, "all_gather", m * (n_pods - 1) * B, 0)
            for _ in range(i, log_inner - 1, -1):
                # cross-pod substage replayed per pod on the gathered rows
                merge_split_rec(i + 1, n_pods)
            j0 = log_inner - 1
        for j in range(j0, -1, -1):
            cross = (1 << j) >= m_inner
            rec(i + 1, "ppermute", m * B if cross else 0,
                0 if cross else m * B)
            merge_split_rec(i + 1, 1)
    return out


#: exchange_schedule op name -> HLO collective opcode
SCHEDULE_TO_HLO = {"all_to_all": "all-to-all", "all_gather": "all-gather",
                   "ppermute": "collective-permute"}


def collective_census(n: int, sizes: Sequence[int],
                      policy: LocalisationPolicy,
                      num_workers: Optional[int] = None,
                      itemsize: int = 4,
                      local_phase: Optional[str] = None) -> Dict[str, Dict]:
    """The analytic collective budget, keyed by HLO opcode.

    Folds `exchange_schedule`'s per-level records into per-device totals:
    ``{hlo_kind: {"count": executions, "wire_bytes": bytes sent per
    device}}``.  Schedule bytes are summed across devices; the per-device
    wire share (total / m) is exactly what the SPMD module's collectives
    move, so rule R1 can diff this dict against the lowered HLO's census
    with zero tolerance on counts and near-zero on bytes.
    """
    m = math.prod(tuple(sizes))
    out: Dict[str, Dict] = {}
    for r in exchange_schedule(n, sizes, policy, num_workers=num_workers,
                               itemsize=itemsize, local_phase=local_phase):
        kind = SCHEDULE_TO_HLO.get(r["op"])
        if kind is None:
            continue                       # local compute record
        e = out.setdefault(kind, {"count": 0, "wire_bytes": 0.0})
        e["count"] += 1
        e["wire_bytes"] += (r["inter_pod_bytes"] + r["intra_pod_bytes"]) / m
    return out


def make_engine_fn(mesh: Optional[Mesh], policy: LocalisationPolicy,
                   num_workers: Optional[int] = None,
                   local_sort: LocalSort = "bitonic",
                   interpret: bool = True, axis: Axis = AXIS,
                   local_phase: Optional[str] = None):
    """Jitted engine sort for one Table-1 case; input donated (step 5)."""
    from repro.core.sort import sort_entry          # local: avoid cycle
    resolve_local_phase(local_phase, local_sort)    # fail fast, not at trace
    if mesh is None:
        a = axis if isinstance(axis, str) else axis[-1]
        mesh = jax.make_mesh((len(jax.devices()),), (a,))
        axis = a
    axes = axis_tuple(axis)
    m = math.prod(_axes_sizes(mesh, axes))
    hash_homed = policy.homing == Homing.HASH_INTERLEAVED
    granule = engine_granule(m, num_workers, hash_homed)
    fn = partial(shard_map_sort, mesh=mesh, policy=policy,
                 num_workers=num_workers, local_sort=local_sort,
                 interpret=interpret, axis=axis, local_phase=local_phase)
    entry = sort_entry(jax.jit(fn, donate_argnums=(0,)), granule)
    sizes = _axes_sizes(mesh, axes)

    @functools.wraps(entry)
    def traced(x, *a, **kw):
        tr = get_tracer()
        if not tr.enabled:
            return entry(x, *a, **kw)
        x = jnp.asarray(x)
        n = int(x.shape[0])
        itemsize = jnp.dtype(x.dtype).itemsize
        cid = next(_SORT_CALLS)
        # the span stamps everything the reconciler needs to recompute
        # exchange_schedule(n, sizes, policy) and check the stamped
        # per-level budgets against it — the trace carries the analytic
        # byte budget right next to the scheduler's observed charges
        with tr.span("engine.sort", cat="engine", call=cid, n=n,
                     sizes=list(sizes), num_workers=num_workers,
                     itemsize=itemsize, local_phase=local_phase,
                     policy={"localised": policy.localised,
                             "static_mapping": policy.static_mapping,
                             "homing": policy.homing.name,
                             "outer": policy.outer}) as sp:
            for lr in exchange_schedule(n, sizes, policy,
                                        num_workers=num_workers,
                                        itemsize=itemsize,
                                        local_phase=local_phase):
                sp.event("engine.exchange_level", call=cid, **lr)
            return entry(x, *a, **kw)

    traced.lower = entry.lower
    traced.__wrapped__ = entry.__wrapped__
    return traced
