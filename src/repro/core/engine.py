"""Explicit `shard_map` execution engine for the distributed merge sort.

The constraint backend (`core/sort.py`, backend="constraint") only *hints*
layouts with `with_sharding_constraint` and leaves collective choice to the
XLA SPMD partitioner — exactly the "leave it to the scheduler" baseline the
paper argues against.  This engine instead implements Algorithms 1-3
literally, per device:

  1. chunk ownership comes from `chunk_bounds` (paper step 1/2) — after BIG
     padding every device owns one equal, contiguous logical chunk;
  2. the worker->core map is the mesh order, fixed at trace time (step 3 —
     the engine *is* the static mapping; `policy.static_mapping` has no
     runtime-chosen analogue here and is ignored);
  3. the per-device local sort runs the Pallas `bitonic_sort` kernel inside
     each shard — the VMEM-resident `input_cpy` of Algorithm 2;
  4. the log2(m)-level merge tree exchanges runs with *explicit* collectives
     chosen by `LocalisationPolicy`:

       localised      — one-shot relayout into the locally-homed chunk
                        layout (`lax.all_to_all` when the input is
                        hash-interleaved, free when chunk-contiguous), then a
                        block-wise bitonic merge-split network: log2(m)
                        stages, stage i making i+1 pairwise chunk exchanges
                        with device d XOR 2^j via `lax.ppermute` —
                        neighbour-only traffic, O(n/m) memory per device,
                        data never re-homed.
       non-localised  — intermediate runs stay pinned to the *input* homing
                        between levels, so every level re-reads the whole
                        array remotely (`lax.all_gather`, the full exchange
                        the paper charges to hash-for-home), merges, and
                        scatters its own home shard back.  Under
                        hash-interleaving every element of a worker's run
                        lives on another device — the per-level all-to-all
                        of Table 1 cases 1/3.

Two distance classes (the NUCA gradient of a multi-pod deployment — fast
ICI within a pod, slow DCN across pods) enter through `axis`: a *tuple* of
mesh axes, outer (pod) axes first, linearised row-major so device
d = pod * n_inner + inner owns logical chunk d.  Merge-split strides that
stay below the inner-axis size toggle only the inner index — those
exchanges run as intra-pod `ppermute`s on the fast axis.  Strides at or
above it toggle only pod bits; how they cross the slow link is the
policy's `outer` knob:

  outer=None          — flat: cross-pod substages are the same pairwise
                        chunk `ppermute`s, just routed over the pod axis
                        (stride-many DCN round trips per top stage).
  outer="hash"/
  "replicate"         — hierarchical: each top stage's cross-pod substages
                        collapse into ONE `all_gather` over the pod axes
                        (the n_pods chunks at my inner index), and every
                        pod replays the stage's cross-pod merge-splits
                        locally on the gathered copies — one DCN collective
                        per top level, merge work replicated, ownership
                        never migrating across pods.  Only the top
                        log2(n_pods) levels touch DCN at all.

The engine returns the same logical sorted array as `jnp.sort`, placed
chunk-contiguous when localised and in the input homing otherwise.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.core.homing import Axis, Homing, axis_tuple
from repro.core.localisation import LocalisationPolicy, chunk_bounds
from repro.core.sort import (check_pad_outside_trace, merge_sorted,
                             pad_to_multiple, pad_value)
from repro.kernels.bitonic_sort import bitonic_sort

AXIS = "data"

_merge_rows = jax.vmap(merge_sorted)

LocalSort = Union[str, Callable]


def _axes_sizes(mesh: Mesh, axes: Tuple[str, ...]) -> Tuple[int, ...]:
    sizes = tuple(mesh.shape[a] for a in axes)
    for a, s in zip(axes, sizes):
        assert (s & (s - 1)) == 0, f"axis {a!r} size {s} not a power of 2"
    return sizes


def _axis_name(axes: Tuple[str, ...]):
    """The collective axis-name argument: bare name or tuple (linearised)."""
    return axes[0] if len(axes) == 1 else axes


def engine_granule(m: int, num_workers: Optional[int],
                   hash_homed: bool) -> int:
    """The engine's padding granule: the chunk must split into per-device
    leaves, and (when relaying out of the interleaved homing) into one
    all-to-all block per peer device.  The one definition shared by
    `shard_map_sort` (in-trace no-op re-pad), `make_engine_fn` (the eager
    pad that must match it) and `exchange_schedule` (the byte model)."""
    w = num_workers or m
    assert w % m == 0 and (w & (w - 1)) == 0, (w, m)
    return m * math.lcm(w // m, m if hash_homed else 1)


def _stride_axis(axes: Tuple[str, ...], sizes: Tuple[int, ...],
                 j: int) -> Tuple[str, int]:
    """Which mesh axis bit j of the linearised device index lives on.

    Row-major linearisation with power-of-two sizes means stride 2^j over
    the combined index toggles exactly one bit of exactly one axis's local
    index: returns (axis_name, local_stride).
    """
    bit = j
    for a, s in zip(reversed(axes), reversed(sizes)):
        la = s.bit_length() - 1
        if bit < la:
            return a, 1 << bit
        bit -= la
    raise ValueError(f"stride 2^{j} exceeds the {math.prod(sizes)}-device space")


def _leaf_sort(rows, local_sort: LocalSort, interpret: bool):
    """Sort each leaf row. rows: (k, leaf) -> (k, leaf) row-sorted.

    local_sort="bitonic" pads each row to the next power of two with BIG
    sentinels (they sort to the tail, so `[:, :leaf]` strips them) and runs
    one kernel grid step per leaf, entirely in VMEM. A callable is applied
    as `local_sort(rows, axis=-1)`.
    """
    if callable(local_sort):
        return local_sort(rows, axis=-1)
    if local_sort != "bitonic":
        raise ValueError(f"unknown local_sort {local_sort!r}")
    k, leaf = rows.shape
    L = 1 << max(0, (leaf - 1).bit_length())
    if L != leaf:
        fill = jnp.full((k, L - leaf), pad_value(rows.dtype), rows.dtype)
        rows = jnp.concatenate([rows, fill], axis=1)
    return bitonic_sort(rows, interpret=interpret)[:, :leaf]


def _merge_split(run, other, chunk: int, keep_low):
    """One compare-exchange of the block bitonic network: merge, keep half."""
    both = merge_sorted(run, other)                  # (2*chunk,)
    return jnp.where(keep_low, both[:chunk], both[chunk:])


def _localised_shard(xloc, *, m: int, chunk: int, w_per_dev: int,
                     hash_homed: bool, local_sort: LocalSort, interpret: bool,
                     axes: Tuple[str, ...], sizes: Tuple[int, ...],
                     hier: bool):
    """Per-device body, localised: one-shot relayout + merge-split tree."""
    name = _axis_name(axes)
    if hash_homed:
        # Algorithm 2's memcpy: one explicit all-to-all turns my interleaved
        # column into my contiguous chunk (order scrambled; the sort fixes it).
        blocks = xloc.reshape(m, chunk // m)     # block j goes to device j
        mine = jax.lax.all_to_all(blocks, name, 0, 0).reshape(-1)
    else:
        mine = xloc                       # already the locally-homed chunk
    runs = _leaf_sort(mine.reshape(w_per_dev, chunk // w_per_dev),
                      local_sort, interpret)
    while runs.shape[0] > 1:              # merge my own leaves, no traffic
        runs = _merge_rows(runs[0::2], runs[1::2])
    run = runs[0]
    # block-wise bitonic merge-split network over the hypercube: stage i
    # sorts runs of 2^(i+1) blocks; each substage swaps the full chunk with
    # device d XOR 2^j, merges, and keeps the low or high half.  Per-device
    # memory stays at chunk size — no device ever materialises more than a
    # pod's worth of chunks — and the sorted array ends naturally distributed
    # in ownership order (compare-exchange -> merge-split block sorting is
    # exact by the 0-1 principle, given sorted blocks).
    d = jax.lax.axis_index(name)          # linearised (pod-major) device id
    m_inner = sizes[-1]
    log_inner = m_inner.bit_length() - 1
    n_pods = m // m_inner
    outer = _axis_name(axes[:-1]) if len(axes) > 1 else None
    pods_idx = jnp.arange(n_pods)
    for i in range(m.bit_length() - 1):
        j0 = i
        if hier and i >= log_inner:
            # hierarchical top level: ONE all_gather over the pod axes pulls
            # the n_pods chunks at my inner index; this stage's cross-pod
            # substages (j = i..log_inner — they toggle only pod bits, so
            # everything they read sits in the gathered set) are replayed
            # locally for every pod, then I keep my own pod's chunk.  One
            # DCN collective replaces (i - log_inner + 1) pairwise DCN hops.
            pods = jax.lax.all_gather(run, outer, axis=0)  # (n_pods, chunk)
            for j in range(i, log_inner - 1, -1):
                t = 1 << (j - log_inner)            # pod-index stride
                partner = pods[pods_idx ^ t]
                # device (q, inner) bits above log_inner are q's bits:
                asc = ((pods_idx >> (i + 1 - log_inner)) & 1) == 0
                low = ((pods_idx >> (j - log_inner)) & 1) == 0
                merged = _merge_rows(pods, partner)  # (n_pods, 2*chunk)
                keep_low = (low == asc)[:, None]
                pods = jnp.where(keep_low, merged[:, :chunk],
                                 merged[:, chunk:])
            run = jnp.take(pods, d >> log_inner, axis=0)
            j0 = log_inner - 1                      # intra-pod substages left
        for j in range(j0, -1, -1):
            ax, t = _stride_axis(axes, sizes, j)
            na = sizes[axes.index(ax)]
            perm = [(a, a ^ t) for a in range(na)]
            other = jax.lax.ppermute(run, ax, perm)  # neighbour-only traffic
            ascending = ((d >> (i + 1)) & 1) == 0
            is_low = ((d >> j) & 1) == 0
            run = _merge_split(run, other, chunk, is_low == ascending)
    return run


def _unlocalised_shard(xloc, *, m: int, chunk: int, w: int,
                       hash_homed: bool, local_sort: LocalSort,
                       interpret: bool, axes: Tuple[str, ...]):
    """Per-device body, non-localised: runs stay home-pinned between levels.

    Every level gathers the whole array (each worker's reads are remote —
    under hash homing literally every element comes from another device),
    does the level's merges, and writes back only its own home shard.  The
    merge work is replicated across devices: without ownership there is no
    cheap way to partition it, which is the paper's point.  On a pod mesh
    every one of these gathers is a full cross-pod exchange — the DCN bill
    the hierarchical policy exists to avoid.
    """
    name = _axis_name(axes)
    d = jax.lax.axis_index(name)

    if hash_homed:
        def gather(col):                          # (chunk, 1) -> (n_p,)
            full = jax.lax.all_gather(col, name, axis=1, tiled=True)
            return full.reshape(-1)

        def scatter(full):                        # (n_p,) -> (chunk, 1)
            return jax.lax.dynamic_slice(
                full.reshape(chunk, m), (0, d), (chunk, 1))
    else:
        def gather(blk):                          # (chunk,) -> (n_p,)
            return jax.lax.all_gather(blk, name, axis=0, tiled=True)

        def scatter(full):                        # (n_p,) -> (chunk,)
            return jax.lax.dynamic_slice(full, (d * chunk,), (chunk,))

    n_p = chunk * m
    full = gather(xloc)                           # leaves: remote read
    runs = _leaf_sort(full.reshape(w, n_p // w), local_sort, interpret)
    xloc = scatter(runs.reshape(-1))
    for _ in range(w.bit_length() - 1):
        full = gather(xloc)                       # per-level full exchange
        runs = full.reshape(runs.shape[0], -1)
        runs = _merge_rows(runs[0::2], runs[1::2])
        xloc = scatter(runs.reshape(-1))
    return xloc


def shard_map_sort(x, mesh: Mesh,
                   policy: LocalisationPolicy = LocalisationPolicy(),
                   num_workers: Optional[int] = None,
                   local_sort: LocalSort = "bitonic",
                   interpret: bool = True, axis: Axis = AXIS):
    """Sort a 1-D array with the explicit shard_map engine (traceable)."""
    axes = axis_tuple(axis)
    sizes = _axes_sizes(mesh, axes)
    n = x.shape[0]
    m = math.prod(sizes)
    w = num_workers or m
    w_per_dev = w // m
    hash_homed = policy.homing == Homing.HASH_INTERLEAVED
    hier = policy.outer is not None
    if hier and len(axes) < 2:
        raise ValueError(
            f"hierarchical policy {policy.name!r} needs a (pod, ..., inner) "
            f"axis tuple, got {axis!r} — use a flat policy on one axis")

    granule = engine_granule(m, num_workers, hash_homed)
    check_pad_outside_trace(n, granule, mesh, axes, "shard_map_sort")
    x = pad_to_multiple(x, granule)
    n_p = x.shape[0]
    bounds = chunk_bounds(n_p, m)                  # ownership, paper step 1
    chunk = bounds[0][1] - bounds[0][0]
    assert all(hi - lo == chunk for lo, hi in bounds)

    spec_axis = axes[0] if len(axes) == 1 else axes   # P entry: name | tuple
    if hash_homed:
        # logical element i*m + d sits in row i of device d's column
        xin = x.reshape(chunk, m)
        in_spec = P(None, spec_axis)
    else:
        xin = x
        in_spec = P(spec_axis)

    if policy.localised:
        body = partial(_localised_shard, m=m, chunk=chunk,
                       w_per_dev=w_per_dev, hash_homed=hash_homed,
                       local_sort=local_sort, interpret=interpret,
                       axes=axes, sizes=sizes, hier=hier)
        out_spec = P(spec_axis)                    # chunk-contiguous output
    else:
        body = partial(_unlocalised_shard, m=m, chunk=chunk, w=w,
                       hash_homed=hash_homed, local_sort=local_sort,
                       interpret=interpret, axes=axes)
        out_spec = in_spec                         # output stays home-pinned

    y = shard_map(body, mesh=mesh, in_specs=in_spec, out_specs=out_spec,
                  check_rep=False)(xin)
    if y.ndim == 2:                                # interleaved view -> logical
        y = y.reshape(-1)
    return y[:n]


def exchange_schedule(n: int, sizes: Sequence[int],
                      policy: LocalisationPolicy,
                      num_workers: Optional[int] = None,
                      itemsize: int = 4) -> List[Dict]:
    """The engine's exchange plan as per-level byte counts (paper Fig 9).

    `sizes` are the sort-axis sizes in axis order, inner (ICI) last — e.g.
    (2, 4) for a ("pod", "data") mesh slice.  Returns one record per
    collective in execution order: ``level`` (0 = relayout, k = merge level
    k), ``op``, and total ``inter_pod_bytes`` / ``intra_pod_bytes`` moved
    across all devices — bytes are hardware-independent facts of the
    schedule, the measurable form of the paper's locality argument.  Must
    mirror the shard_map bodies above; the structure tests pin them to the
    lowered HLO's collective counts.
    """
    sizes = tuple(sizes)
    m = math.prod(sizes)
    m_inner = sizes[-1]
    n_pods = m // m_inner
    w = num_workers or m
    hash_homed = policy.homing == Homing.HASH_INTERLEAVED
    hier = policy.outer is not None
    if hier and len(sizes) < 2:
        raise ValueError(
            f"hierarchical policy {policy.name!r} needs (pod, ..., inner) "
            f"axis sizes, got {sizes!r} — same contract as shard_map_sort")
    granule = engine_granule(m, num_workers, hash_homed)
    n_p = n + (-n) % granule
    B = (n_p // m) * itemsize                       # one chunk, in bytes
    log_inner = m_inner.bit_length() - 1
    out: List[Dict] = []

    def rec(level, op, inter, intra):
        out.append({"level": level, "op": op,
                    "inter_pod_bytes": inter, "intra_pod_bytes": intra})

    if not policy.localised:
        # leaf gather + one full gather per merge level: every device
        # re-reads everything it doesn't hold, at every level.
        for lvl in range(w.bit_length()):
            rec(lvl, "all_gather",
                m * (m - m_inner) * B, m * (m_inner - 1) * B)
        return out

    if hash_homed:
        # one-shot relayout: each device sends m-1 of its m chunk-blocks
        rec(0, "all_to_all",
            m * (m - m_inner) * (B // m), m * (m_inner - 1) * (B // m))
    for i in range(m.bit_length() - 1):
        j0 = i
        if hier and i >= log_inner:
            rec(i + 1, "all_gather", m * (n_pods - 1) * B, 0)
            j0 = log_inner - 1
        for j in range(j0, -1, -1):
            cross = (1 << j) >= m_inner
            rec(i + 1, "ppermute", m * B if cross else 0,
                0 if cross else m * B)
    return out


def make_engine_fn(mesh: Optional[Mesh], policy: LocalisationPolicy,
                   num_workers: Optional[int] = None,
                   local_sort: LocalSort = "bitonic",
                   interpret: bool = True, axis: Axis = AXIS):
    """Jitted engine sort for one Table-1 case; input donated (step 5)."""
    from repro.core.sort import sort_entry          # local: avoid cycle
    if mesh is None:
        a = axis if isinstance(axis, str) else axis[-1]
        mesh = jax.make_mesh((len(jax.devices()),), (a,))
        axis = a
    axes = axis_tuple(axis)
    m = math.prod(_axes_sizes(mesh, axes))
    hash_homed = policy.homing == Homing.HASH_INTERLEAVED
    granule = engine_granule(m, num_workers, hash_homed)
    fn = partial(shard_map_sort, mesh=mesh, policy=policy,
                 num_workers=num_workers, local_sort=local_sort,
                 interpret=interpret, axis=axis)
    return sort_entry(jax.jit(fn, donate_argnums=(0,)), granule)
