"""Explicit `shard_map` execution engine for the distributed merge sort.

The constraint backend (`core/sort.py`, backend="constraint") only *hints*
layouts with `with_sharding_constraint` and leaves collective choice to the
XLA SPMD partitioner — exactly the "leave it to the scheduler" baseline the
paper argues against.  This engine instead implements Algorithms 1-3
literally, per device:

  1. chunk ownership comes from `chunk_bounds` (paper step 1/2) — after BIG
     padding every device owns one equal, contiguous logical chunk;
  2. the worker->core map is the mesh order, fixed at trace time (step 3 —
     the engine *is* the static mapping; `policy.static_mapping` has no
     runtime-chosen analogue here and is ignored);
  3. the per-device local sort runs the Pallas `bitonic_sort` kernel inside
     each shard — the VMEM-resident `input_cpy` of Algorithm 2;
  4. the log2(m)-level merge tree exchanges runs with *explicit* collectives
     chosen by `LocalisationPolicy`:

       localised      — one-shot relayout into the locally-homed chunk
                        layout (`lax.all_to_all` when the input is
                        hash-interleaved, free when chunk-contiguous), then a
                        block-wise bitonic merge-split network: log2(m)
                        stages, stage i making i+1 pairwise chunk exchanges
                        with device d XOR 2^j via `lax.ppermute` —
                        neighbour-only traffic, O(n/m) memory per device,
                        data never re-homed.
       non-localised  — intermediate runs stay pinned to the *input* homing
                        between levels, so every level re-reads the whole
                        array remotely (`lax.all_gather`, the full exchange
                        the paper charges to hash-for-home), merges, and
                        scatters its own home shard back.  Under
                        hash-interleaving every element of a worker's run
                        lives on another device — the per-level all-to-all
                        of Table 1 cases 1/3.

The engine returns the same logical sorted array as `jnp.sort`, placed
chunk-contiguous when localised and in the input homing otherwise.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.homing import Homing
from repro.core.localisation import LocalisationPolicy, chunk_bounds
from repro.core.sort import merge_sorted, pad_to_multiple, pad_value
from repro.kernels.bitonic_sort import bitonic_sort

AXIS = "data"

_merge_rows = jax.vmap(merge_sorted)

LocalSort = Union[str, Callable]


def _leaf_sort(rows, local_sort: LocalSort, interpret: bool):
    """Sort each leaf row. rows: (k, leaf) -> (k, leaf) row-sorted.

    local_sort="bitonic" pads each row to the next power of two with BIG
    sentinels (they sort to the tail, so `[:, :leaf]` strips them) and runs
    one kernel grid step per leaf, entirely in VMEM. A callable is applied
    as `local_sort(rows, axis=-1)`.
    """
    if callable(local_sort):
        return local_sort(rows, axis=-1)
    if local_sort != "bitonic":
        raise ValueError(f"unknown local_sort {local_sort!r}")
    k, leaf = rows.shape
    L = 1 << max(0, (leaf - 1).bit_length())
    if L != leaf:
        fill = jnp.full((k, L - leaf), pad_value(rows.dtype), rows.dtype)
        rows = jnp.concatenate([rows, fill], axis=1)
    return bitonic_sort(rows, interpret=interpret)[:, :leaf]


def _localised_shard(xloc, *, m: int, chunk: int, w_per_dev: int,
                     hash_homed: bool, local_sort: LocalSort,
                     interpret: bool, axis: str = AXIS):
    """Per-device body, localised: one-shot relayout + ppermute tree."""
    if hash_homed:
        # Algorithm 2's memcpy: one explicit all-to-all turns my interleaved
        # column into my contiguous chunk (order scrambled; the sort fixes it).
        blocks = xloc.reshape(m, chunk // m)     # block j goes to device j
        mine = jax.lax.all_to_all(blocks, axis, 0, 0).reshape(-1)
    else:
        mine = xloc                       # already the locally-homed chunk
    runs = _leaf_sort(mine.reshape(w_per_dev, chunk // w_per_dev),
                      local_sort, interpret)
    while runs.shape[0] > 1:              # merge my own leaves, no traffic
        runs = _merge_rows(runs[0::2], runs[1::2])
    run = runs[0]
    # block-wise bitonic merge-split network over the hypercube: stage i
    # sorts runs of 2^(i+1) blocks; each substage swaps the full chunk with
    # device d XOR 2^j (neighbour-only ppermute), merges, and keeps the low
    # or high half.  Per-device memory stays at chunk size — no device ever
    # materialises more than 2 chunks — and the sorted array ends naturally
    # distributed in ownership order (compare-exchange -> merge-split block
    # sorting is exact by the 0-1 principle, given sorted blocks).
    d = jax.lax.axis_index(axis)
    p = m.bit_length() - 1
    for i in range(p):
        for j in range(i, -1, -1):
            stride = 1 << j
            perm = [(a, a ^ stride) for a in range(m)]
            other = jax.lax.ppermute(run, axis, perm)
            both = merge_sorted(run, other)          # (2*chunk,)
            ascending = ((d >> (i + 1)) & 1) == 0
            is_low = ((d >> j) & 1) == 0
            keep_low = is_low == ascending
            run = jnp.where(keep_low, both[:chunk], both[chunk:])
    return run


def _unlocalised_shard(xloc, *, m: int, chunk: int, w: int,
                       hash_homed: bool, local_sort: LocalSort,
                       interpret: bool, axis: str = AXIS):
    """Per-device body, non-localised: runs stay home-pinned between levels.

    Every level gathers the whole array (each worker's reads are remote —
    under hash homing literally every element comes from another device),
    does the level's merges, and writes back only its own home shard.  The
    merge work is replicated across devices: without ownership there is no
    cheap way to partition it, which is the paper's point.
    """
    d = jax.lax.axis_index(axis)

    if hash_homed:
        def gather(col):                          # (chunk, 1) -> (n_p,)
            full = jax.lax.all_gather(col, axis, axis=1, tiled=True)
            return full.reshape(-1)

        def scatter(full):                        # (n_p,) -> (chunk, 1)
            return jax.lax.dynamic_slice(
                full.reshape(chunk, m), (0, d), (chunk, 1))
    else:
        def gather(blk):                          # (chunk,) -> (n_p,)
            return jax.lax.all_gather(blk, axis, axis=0, tiled=True)

        def scatter(full):                        # (n_p,) -> (chunk,)
            return jax.lax.dynamic_slice(full, (d * chunk,), (chunk,))

    n_p = chunk * m
    full = gather(xloc)                           # leaves: remote read
    runs = _leaf_sort(full.reshape(w, n_p // w), local_sort, interpret)
    xloc = scatter(runs.reshape(-1))
    for _ in range(w.bit_length() - 1):
        full = gather(xloc)                       # per-level full exchange
        runs = full.reshape(runs.shape[0], -1)
        runs = _merge_rows(runs[0::2], runs[1::2])
        xloc = scatter(runs.reshape(-1))
    return xloc


def shard_map_sort(x, mesh: Mesh,
                   policy: LocalisationPolicy = LocalisationPolicy(),
                   num_workers: Optional[int] = None,
                   local_sort: LocalSort = "bitonic",
                   interpret: bool = True, axis: str = AXIS):
    """Sort a 1-D array with the explicit shard_map engine (traceable)."""
    n = x.shape[0]
    m = mesh.shape[axis]
    w = num_workers or m
    assert (m & (m - 1)) == 0, f"device count {m} not a power of 2"
    assert w % m == 0 and (w & (w - 1)) == 0, (w, m)
    w_per_dev = w // m
    hash_homed = policy.homing == Homing.HASH_INTERLEAVED

    # chunk must split into per-device leaves, and (when relaying out of the
    # interleaved homing) into one all-to-all block per peer device.
    granule = m * math.lcm(w_per_dev, m if hash_homed else 1)
    x = pad_to_multiple(x, granule)
    n_p = x.shape[0]
    bounds = chunk_bounds(n_p, m)                  # ownership, paper step 1
    chunk = bounds[0][1] - bounds[0][0]
    assert all(hi - lo == chunk for lo, hi in bounds)

    if hash_homed:
        # logical element i*m + d sits in row i of device d's column
        xin = x.reshape(chunk, m)
        in_spec = P(None, axis)
    else:
        xin = x
        in_spec = P(axis)

    if policy.localised:
        body = partial(_localised_shard, m=m, chunk=chunk,
                       w_per_dev=w_per_dev, hash_homed=hash_homed,
                       local_sort=local_sort, interpret=interpret, axis=axis)
        out_spec = P(axis)                         # chunk-contiguous output
    else:
        body = partial(_unlocalised_shard, m=m, chunk=chunk, w=w,
                       hash_homed=hash_homed, local_sort=local_sort,
                       interpret=interpret, axis=axis)
        out_spec = in_spec                         # output stays home-pinned

    y = shard_map(body, mesh=mesh, in_specs=in_spec, out_specs=out_spec,
                  check_rep=False)(xin)
    if y.ndim == 2:                                # interleaved view -> logical
        y = y.reshape(-1)
    return y[:n]


def make_engine_fn(mesh: Optional[Mesh], policy: LocalisationPolicy,
                   num_workers: Optional[int] = None,
                   local_sort: LocalSort = "bitonic",
                   interpret: bool = True, axis: str = AXIS):
    """Jitted engine sort for one Table-1 case; input donated (step 5)."""
    if mesh is None:
        mesh = jax.make_mesh((len(jax.devices()),), (axis,))
    fn = partial(shard_map_sort, mesh=mesh, policy=policy,
                 num_workers=num_workers, local_sort=local_sort,
                 interpret=interpret, axis=axis)
    return jax.jit(fn, donate_argnums=(0,))
