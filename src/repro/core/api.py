"""The unified placement API — the paper's technique as a first-class object.

The paper's contribution is a *programming technique*: decide where data
lives, make that decision once, and write every workload against it.  This
module is that technique's surface.  Two abstractions:

`Locale`
    A frozen bundle of ``(mesh, axis, LocalisationPolicy)`` — the one object
    a caller constructs.  Everything the repo previously did with loose
    free functions hangs off it:

    ==================  ======================================================
    ``locale.put(x)``       host→device placement under the policy's homing
                            (was ``to_layout``); returns a `Homed`.
    ``locale.pin(x)``       in-jit sharding constraint per policy (was
                            ``place``/``constrain``); no-op without a mesh or
                            under ``static_mapping=False``.
    ``locale.localise(x)``  the one-shot Algorithm-2 relayout into the
                            chunk-contiguous locally-homed layout.
    ``locale.pin_tree(t)``  `localise` applied leaf-wise to a pytree along a
                            chosen dim (KV-cache slot homing).
    ``locale.jit(fn)``      policy-aware jit with step-5 donation
                            ('free as soon as finished').
    ``locale.make(s, cb)``  data *born* locally homed: per-device callback
                            materialisation (the data-pipeline path).
    ``locale.workload(n)``  registry factory subsuming ``make_sort_fn`` /
                            ``make_engine_fn`` / ``make_microbench_fn``,
                            with unified ``backend=`` selection.
    ==================  ======================================================

`Homed`
    A registered pytree wrapping ``(data, homing, axis)``.  The layout
    metadata travels *with* the array: ``.logical()`` recovers logical
    1-D order automatically (was ``logical_view``), and because the homing
    is pytree *aux data*, combining two differently-homed values in any
    ``jax.tree`` operation raises a structure mismatch — mixed-homing bugs
    become type errors instead of silent wrong layouts.

Table-1 knob mapping: ``policy.localised`` (copy into locally-homed buffers),
``policy.static_mapping`` (explicit layouts vs compiler-chosen), and
``policy.homing`` (LOCAL_CHUNKED vs HASH_INTERLEAVED) — see `README.md`.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.homing import (Axis, Homing, check_divisible, logical_view,
                               to_layout)
from repro.core.homing import axis_size as _mesh_axis_size
from repro.core.localisation import LocalisationPolicy, localise, place


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class Homed:
    """An array plus the homing it was placed under.

    `data` is stored in *placed* form: 1-D for LOCAL_CHUNKED, the (n/N, N)
    stripe view for HASH_INTERLEAVED on a mesh (row-major reshape recovers
    logical order).  `homing` and `axis` are pytree aux data, so a `Homed`
    passes through `jit`/`tree_map` transparently while tree operations over
    mixed homings fail loudly with a treedef mismatch.
    """
    data: Any
    homing: Homing = Homing.LOCAL_CHUNKED
    axis: Axis = "data"

    def tree_flatten(self):
        return (self.data,), (self.homing, self.axis)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)

    def logical(self):
        """The logical 1-D order (lazy; free for LOCAL_CHUNKED)."""
        return logical_view(self.data, self.homing)

    @property
    def dtype(self):
        return self.data.dtype

    @property
    def shape(self):
        return self.data.shape

    @property
    def size(self) -> int:
        return math.prod(self.data.shape)


# ---------------------------------------------------------------------------
# workload registry
# ---------------------------------------------------------------------------
_WORKLOADS: Dict[str, Callable] = {}


def register_workload(name: str):
    """Register a factory ``builder(locale, **kw) -> jitted fn`` under `name`.

    New workloads (striped pipelines, served caches, multi-host sorts) plug
    into `Locale.workload` here instead of growing another ``make_*_fn``.
    """
    def deco(builder: Callable) -> Callable:
        _WORKLOADS[name] = builder
        return builder
    return deco


def workload_names() -> Tuple[str, ...]:
    """The registered workload names (homecheck's discovery surface)."""
    return tuple(sorted(_WORKLOADS))


@dataclass(frozen=True)
class Locale:
    """Where data lives: ``(mesh, axis, policy)`` as one first-class value.

    ``mesh=None`` is the single-device degenerate locale: every placement
    method becomes the identity, so workload code is written once and runs
    unchanged from a laptop to a pod.  `axis` may be a tuple of mesh axes,
    outer (slow, DCN) axes first — ``Locale(mesh, axis=("pod", "data"))``
    linearises devices pod-major, and every placement method (`put`, `pin`,
    `localise`, `make`) and workload (`workload("sort",
    backend="shard_map")` — the hierarchical engine) works across both
    hierarchy levels.
    """
    mesh: Optional[Mesh] = None
    axis: Axis = "data"
    policy: LocalisationPolicy = LocalisationPolicy()

    # -- construction helpers ------------------------------------------------
    @classmethod
    def auto(cls, policy: LocalisationPolicy = LocalisationPolicy(),
             axis: str = "data", devices=None) -> "Locale":
        """A locale over all (or the given) devices; mesh=None when only one."""
        devices = list(jax.devices()) if devices is None else list(devices)
        if len(devices) <= 1:
            return cls(mesh=None, axis=axis, policy=policy)
        mesh = jax.make_mesh((len(devices),), (axis,), devices=devices)
        return cls(mesh=mesh, axis=axis, policy=policy)

    def with_policy(self, policy: LocalisationPolicy) -> "Locale":
        """Same placement substrate, different Table-1 policy corner."""
        return Locale(mesh=self.mesh, axis=self.axis, policy=policy)

    # -- mesh geometry -------------------------------------------------------
    @property
    def axis_size(self) -> int:
        """#devices along the locale's axis (1 without a mesh)."""
        if self.mesh is None:
            return 1
        return _mesh_axis_size(self.mesh, self.axis)

    def spec(self, ndim: int = 1) -> P:
        """Chunk-contiguous spec: leading dim owned per-device, rest whole."""
        return P(self.axis, *([None] * (ndim - 1)))

    def owners(self, size: int) -> Tuple[int, ...]:
        """Home-device index of each of `size` chunk-contiguously homed items.

        The ownership map of `chunk_bounds` (paper step 1/2 — the same math
        the engine uses for sort chunks), applied to any per-item axis:
        ``owners(B)[s]`` is the linearised (pod-major on tuple axes) device
        index that item/slot ``s`` lives on.  The serving scheduler routes,
        batches and evicts decode slots with exactly this map.  Without a
        mesh every item is homed on the single device 0.
        """
        from repro.core.localisation import chunk_bounds
        out: list = []
        for dev, (lo, hi) in enumerate(chunk_bounds(size, self.axis_size)):
            out.extend([dev] * (hi - lo))
        return tuple(out)

    def sharding(self, ndim: int = 1) -> Optional[NamedSharding]:
        """The chunk-contiguous NamedSharding (None without a mesh)."""
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(ndim))

    # -- placement -----------------------------------------------------------
    def put(self, x, pad: bool = False) -> Homed:
        """Host→device placement of a 1-D array under the policy's homing.

        Replaces ``to_layout``.  Lengths must divide the axis size; with
        ``pad=True`` the input is extended with BIG sort-neutral sentinels
        (``pad_to_multiple``, granule = the locale's axis size) — the
        `Homed.logical()` view then carries the sentinel tail, which
        sorts/strips exactly like the sort's padding.
        """
        if pad:
            from repro.core.sort import pad_to_multiple
            x = pad_to_multiple(x, self.axis_size)
        if self.mesh is None:
            import jax.numpy as jnp
            return Homed(jnp.asarray(x), self.policy.homing, self.axis)
        if self.policy.homing == Homing.HASH_INTERLEAVED:
            placed = to_layout(x, self.mesh, self.policy.homing, self.axis)
            return Homed(placed, self.policy.homing, self.axis)
        check_divisible(x.shape[0], self.axis_size, self.policy.homing,
                        str(self.axis))
        placed = jax.device_put(x, self.sharding(getattr(x, "ndim", 1)))
        return Homed(placed, self.policy.homing, self.axis)

    def pin(self, x):
        """In-jit layout constraint per the policy (replaces place/constrain).

        A strict no-op when ``mesh is None`` or ``static_mapping=False`` —
        the 'leave it to the compiler' baseline stays a baseline.  Accepts a
        raw array or a `Homed` (returned re-wrapped).
        """
        if isinstance(x, Homed):
            if self.mesh is None or not self.policy.static_mapping:
                return x                         # no-op before any checking
            if x.homing != self.policy.homing:
                raise TypeError(
                    f"cannot pin a {x.homing.value!r}-homed array under a "
                    f"{self.policy.homing.value!r} locale — re-place it with "
                    f"Locale.put or relayout with Locale.localise")
            # constrain via the logical view, then restore the stored placed
            # form so same-homing Homed values stay shape-compatible
            pinned = self.pin(x.logical())
            return Homed(pinned.reshape(x.data.shape), x.homing, x.axis)
        if self.mesh is None or not self.policy.static_mapping:
            return x
        return place(x, self.mesh, self.policy, self.axis)

    def localise(self, x):
        """The one-shot Algorithm-2 relayout into the locally-homed layout."""
        if isinstance(x, Homed):
            return Homed(localise(x.logical(), self.mesh, self.axis),
                         Homing.LOCAL_CHUNKED, self.axis)
        return localise(x, self.mesh, self.axis)

    def pin_tree(self, tree, dim: int = 0, size: Optional[int] = None):
        """Home every pytree leaf chunk-contiguously along `dim`.

        The KV-cache form of localisation: each slot along `dim` (a batch
        slot, a request) lives wholly on the device that computes it.  Leaves
        where `dim` doesn't exist, doesn't match `size`, or doesn't divide
        the axis are left unconstrained (replicated small state).  No-op
        without a mesh or under ``static_mapping=False``.
        """
        if self.mesh is None or not self.policy.static_mapping:
            return tree
        N = self.axis_size

        def leaf(x):
            if getattr(x, "ndim", 0) <= dim:
                return x
            if size is not None and x.shape[dim] != size:
                return x
            if x.shape[dim] % N != 0:
                return x
            spec = [None] * x.ndim
            spec[dim] = self.axis
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(self.mesh, P(*spec)))

        return jax.tree.map(leaf, tree)

    # -- execution -----------------------------------------------------------
    def jit(self, fn, donate=(0,), **jit_kw):
        """Policy-aware jit: paper step 5 ('free as soon as finished') ==
        donating the input buffers the relayout consumes."""
        return jax.jit(fn, donate_argnums=tuple(donate or ()), **jit_kw)

    def make(self, shape: Tuple[int, ...], cb: Callable):
        """An array *born* locally homed: `cb(index)` materialises only the
        chunk each device owns (``jax.make_array_from_callback`` under the
        chunk-contiguous sharding).  Without a mesh, `cb` runs once over the
        full index — same code path, degenerate locale.
        """
        sh = self.sharding(len(shape))
        if sh is None:
            import jax.numpy as jnp
            return jnp.asarray(cb(tuple(slice(None) for _ in shape)))
        return jax.make_array_from_callback(shape, sh, cb)

    def workload(self, name: str, **kw):
        """Build the jitted entry point of a registered workload.

        The one factory behind what used to be ``make_sort_fn`` /
        ``make_engine_fn`` / ``make_microbench_fn``:

            locale.workload("sort", backend="constraint" | "shard_map")
            locale.workload("microbench", reps=R)
        """
        try:
            builder = _WORKLOADS[name]
        except KeyError:
            raise ValueError(f"unknown workload {name!r}; registered: "
                             f"{sorted(_WORKLOADS)}") from None
        return builder(self, **kw)

    def check(self, workload: str = "sort", *, rules=None, suppress=(),
              **kw):
        """Statically verify a workload's lowering against this locale.

        The homecheck hook: lowers ``self.workload(workload, ...)`` for a
        representative input and runs rules R1-R11 (surprise collectives,
        home leaks, VMEM budget, donation audit, pallas write-race/
        coverage, exchange-network certification, index-arithmetic lint,
        dead grid lanes, scheduler certification, HBM live-range,
        collective control flow) over the partitioned HLO, jaxpr, and
        exchange network without executing anything.  Returns an
        `analysis.Report`; ``report.clean`` is the contract.  `rules`
        selects a subset (e.g. ``rules=("R5", "R6")``; None = all);
        `suppress` drops findings by rule id (e.g. ``suppress=("R4",)``).
        R9 applies to the serving target only (other workloads note the
        skip); R10 gates against `repro.kernels.HBM_BYTES_PER_DEVICE`
        unless ``hbm_ceiling=`` overrides it.
        """
        from repro.analysis import check_workload
        return check_workload(self, workload, rules=rules,
                              suppress=suppress, **kw)


# ---------------------------------------------------------------------------
# built-in workloads
# ---------------------------------------------------------------------------
@register_workload("sort")
def _sort_workload(locale: Locale, *, backend: str = "constraint",
                   num_workers=None, local_sort=None, interpret: bool = True,
                   local_phase: str = None):
    """The paper's validation app: distributed merge sort (Algorithms 1-3).

    A tuple locale axis (e.g. ("pod", "data")) selects the two-distance-class
    engine: intra-pod neighbour ppermutes on the fast inner axis, cross-pod
    exchanges per ``policy.outer`` (see `LocalisationPolicy.hierarchical`).

    ``local_phase`` (engine backend) picks the per-device compute:
    "pallas" — the VMEM-resident production path (ONE fused kernel for leaf
    sorts + local merge tree, merge-path merge-splits that compute only the
    kept half); "reference" — the jnp oracle; None — auto by ``local_sort``.
    """
    from repro.core.sort import make_sort_fn
    axis = locale.axis if locale.mesh is not None else "data"
    return make_sort_fn(locale.mesh, locale.policy, num_workers=num_workers,
                        local_sort=local_sort, backend=backend, axis=axis,
                        interpret=interpret, local_phase=local_phase)


@register_workload("engine")
def _engine_workload(locale: Locale, **kw):
    """Alias: the explicit shard_map execution engine backend."""
    kw.setdefault("backend", "shard_map")
    if kw["backend"] != "shard_map":
        raise ValueError("workload('engine') is the shard_map backend; use "
                         "workload('sort', backend=...) to choose freely")
    return _sort_workload(locale, **kw)


@register_workload("microbench")
def _microbench_workload(locale: Locale, *, reps: int):
    """The Fig-1 repetitive-copy micro-benchmark."""
    from repro.core.microbench import make_microbench_fn
    axis = locale.axis if locale.mesh is not None else "data"
    return make_microbench_fn(locale.mesh, locale.policy, reps, axis=axis)
