"""Homing policies — the TPU adaptation of TILEPro64 cache homing.

A *homing* is a layout rule that decides which device owns each element of a
1-D array:

  * LOCAL_CHUNKED  — element i lives on device i // (n/N) (the paper's
                     "local homing": worker w's chunk is contiguous and
                     entirely on w's device).
  * HASH_INTERLEAVED — element i lives on device i mod N (the paper's
                     "hash-for-home" at its finest granularity: any
                     contiguous range a worker touches is spread across
                     every device, so sequential access is always remote).

The interleaved layout is expressed by viewing the array as (n/N, N) and
sharding the *minor* axis — structurally identical to cache-line striping.

These are the layout *mechanics*; the public surface is `repro.core.api`:
`Locale.put` places under a homing (returning a `Homed` wrapper) and
`Locale.pin` emits the in-jit constraint form.
"""
from __future__ import annotations

import enum
import math
from typing import Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# A placement axis: one mesh axis name, or a tuple of names linearised
# row-major (the hierarchical case — e.g. ("pod", "data"): pod-major device
# order, so device d = pod * n_data + data_index owns logical chunk d).
Axis = Union[str, Tuple[str, ...]]


class Homing(enum.Enum):
    LOCAL_CHUNKED = "local"
    HASH_INTERLEAVED = "hash"


def axis_tuple(axis: Axis) -> Tuple[str, ...]:
    return (axis,) if isinstance(axis, str) else tuple(axis)


def axis_size(mesh: Mesh, axis: Axis) -> int:
    """#devices along `axis` — the product over a tuple of mesh axes."""
    return math.prod(mesh.shape[a] for a in axis_tuple(axis))


def chunked_sharding(mesh: Mesh, axis: Axis = "data") -> NamedSharding:
    return NamedSharding(mesh, P(axis))


def interleaved_sharding(mesh: Mesh, axis: Axis = "data") -> NamedSharding:
    return NamedSharding(mesh, P(None, axis))


def check_divisible(n: int, N: int, homing: Homing, axis: str) -> None:
    """Raise a clear error when n elements can't split over N devices.

    Both homings need n % N == 0 (a chunk per device / a full stripe row);
    callers that want arbitrary lengths pad first with
    `repro.core.sort.pad_to_multiple(x, N)` and strip the sentinel tail.
    """
    if n % N != 0:
        raise ValueError(
            f"cannot home {n} elements as {homing.value!r} over the {N} "
            f"devices of mesh axis {axis!r}: {n} % {N} != 0 — pad with "
            f"pad_to_multiple(x, {N}) (sentinels sort to the tail) or pass "
            f"pad=True to Locale.put")


def to_layout(x, mesh: Mesh, homing: Homing, axis: Axis = "data"):
    """Place a 1-D array under the given homing (outside jit)."""
    n = x.shape[0]
    N = axis_size(mesh, axis)
    check_divisible(n, N, homing, str(axis))
    if homing == Homing.LOCAL_CHUNKED:
        return jax.device_put(x, chunked_sharding(mesh, axis))
    return jax.device_put(x.reshape(n // N, N), interleaved_sharding(mesh, axis))


def logical_view(x_placed, homing: Homing):
    """Recover the logical 1-D order from a placed array (lazy, inside jit)."""
    if homing == Homing.LOCAL_CHUNKED:
        return x_placed
    return x_placed.reshape(-1)  # (n/N, N) row-major == logical order


def constrain(x, mesh: Mesh, homing: Homing, axis: Axis = "data"):
    """Sharding constraint form, for use inside jit."""
    if mesh is None:
        return x
    if homing == Homing.LOCAL_CHUNKED:
        return jax.lax.with_sharding_constraint(x, chunked_sharding(mesh, axis))
    n = x.shape[0]
    N = axis_size(mesh, axis)
    check_divisible(n, N, homing, str(axis))
    y = x.reshape(n // N, N)
    y = jax.lax.with_sharding_constraint(y, interleaved_sharding(mesh, axis))
    return y.reshape(n)
