"""The homecheck rule set: R1-R4 over a lowered workload's artifacts.

Each rule is a pure function from extracted facts (the per-op collective
census of `launch.hlo_cost.analyze`, the module-header facts of
`hlo_facts`, the pallas footprints of `vmem`) to `Finding`s appended to a
`Report`.  Rules never trace or compile anything themselves — the
orchestrator (`analysis.homecheck`) produces the artifacts once and feeds
every rule from them.

  R1 surprise-collective — diff the HLO collective census (kind, count,
      per-device wire bytes; `while`-body collectives already scaled by
      trip count) against `engine.collective_census`'s analytic budget.
      A collective the byte model never budgeted is exactly the class of
      silent cost the paper's discipline exists to exclude.
  R2 home-leak — a collective whose device groups vary over a mesh axis
      the locale never declared means GSPMD reshards/reduces homed values
      across an unrelated axis (the PR 3 miscompile class: a padding
      concatenate partitioned over a >1 'model' axis arrived *summed*).
  R3 vmem-budget — per-pallas_call block+scratch footprint vs the per-core
      VMEM ceiling (`repro.kernels.VMEM_BYTES_PER_CORE`).
  R4 donation-audit — a large entry parameter that is not donation-aliased
      but whose exact logical type reappears as an output is a buffer XLA
      must copy every step ('free as soon as finished', paper step 5).

R5/R7/R8 (pallas block schedules and kernel jaxprs) live in
`analysis.kernelcheck`; R6 (exchange-network certification) in
`analysis.netverify`; R9 (scheduler certification) in
`analysis.schedcheck`; R10/R11 (HBM live range, collective control flow)
in `analysis.livecheck`.  The orchestrator runs all eleven.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Set

import numpy as np

from repro.analysis.findings import Finding, Report, Severity
from repro.analysis.hlo_facts import (aliased_param_indices, entry_layout,
                                      type_bytes, type_key)
from repro.analysis.vmem import PallasFootprint

# R1 byte tolerance: the schedule is exact on CPU SPMD lowerings; allow a
# whisker for layout padding on other backends.
R1_REL_TOL = 0.02
R1_ABS_TOL = 4096.0

R4_MIN_BYTES = float(1 << 20)           # audit buffers >= 1 MiB


def r1_surprise_collective(report: Report, coll_ops: List[Dict],
                           predicted: Dict[str, Dict]) -> None:
    """Diff the HLO collective census against the analytic budget."""
    actual: Dict[str, Dict] = {}
    for rec in coll_ops:
        e = actual.setdefault(rec["kind"], {"count": 0.0, "wire": 0.0})
        e["count"] += rec["mult"]
        e["wire"] += rec["wire_bytes"] * rec["mult"]
    for kind in sorted(set(actual) | set(predicted)):
        a = actual.get(kind, {"count": 0.0, "wire": 0.0})
        p = predicted.get(kind, {"count": 0, "wire_bytes": 0.0})
        ca, cp = a["count"], p["count"]
        wa, wp = a["wire"], p["wire_bytes"]
        if ca > cp:
            report.add(Finding(
                "R1", Severity.ERROR, kind,
                predicted_bytes=wp, actual_bytes=wa,
                message=f"unbudgeted collective: HLO has {ca:g} "
                        f"{kind}(s), exchange_schedule budgets {cp:g}"))
        elif ca < cp:
            report.add(Finding(
                "R1", Severity.WARN, kind,
                predicted_bytes=wp, actual_bytes=wa,
                message=f"budgeted {kind} missing from HLO "
                        f"({ca:g} < {cp:g}) — compiler elided or fused; "
                        f"the byte model overestimates this case"))
        elif abs(wa - wp) > max(R1_ABS_TOL, R1_REL_TOL * max(wa, wp)):
            report.add(Finding(
                "R1", Severity.WARN, kind,
                predicted_bytes=wp, actual_bytes=wa,
                message=f"{kind} count matches ({ca:g}) but per-device "
                        f"wire bytes diverge beyond tolerance"))


def _varied_axes(groups: List[List[int]], axis_names: Sequence[str],
                 axis_sizes: Sequence[int]) -> Set[str]:
    """Mesh axes over which the collective's device groups vary.

    Device ids are logical partition ids == positions in the mesh's
    row-major device flattening, so coords come from unravel_index over the
    mesh shape.  Empty `groups` (HLO `replica_groups={}`) means every
    device participates: all >1-size axes vary.
    """
    shape = tuple(axis_sizes)
    if not groups:
        return {a for a, s in zip(axis_names, shape) if s > 1}
    nd = int(np.prod(shape))
    varied: Set[str] = set()
    for g in groups:
        if len(g) < 2:
            continue
        coords = [np.unravel_index(p, shape) for p in g if p < nd]
        for k, name in enumerate(axis_names):
            if len({c[k] for c in coords}) > 1:
                varied.add(name)
    return varied


def r2_home_leak(report: Report, coll_ops: List[Dict],
                 axis_names: Sequence[str], axis_sizes: Sequence[int],
                 allowed_axes: Sequence[str]) -> None:
    """Flag collectives whose groups span an undeclared mesh axis."""
    allowed = set(allowed_axes)
    for rec in coll_ops:
        varied = _varied_axes(rec["groups"], axis_names, axis_sizes)
        leak = varied - allowed
        if leak:
            report.add(Finding(
                "R2", Severity.ERROR, rec["kind"],
                actual_bytes=rec["wire_bytes"] * rec["mult"],
                message=f"device groups vary over undeclared mesh "
                        f"axis(es) {sorted(leak)} (declared: "
                        f"{sorted(allowed)}) — GSPMD is moving homed "
                        f"values across an axis the locale never uses; "
                        f"groups={rec['groups'] or 'all devices'}"))


def r3_vmem_budget(report: Report, footprints: List[PallasFootprint],
                   ceiling_bytes: int) -> None:
    """Flag pallas_calls whose resident footprint exceeds the ceiling."""
    for fp in footprints:
        if fp.total_bytes > ceiling_bytes:
            report.add(Finding(
                "R3", Severity.ERROR, "pallas_call",
                shape=", ".join(f"{s}:{d}" for s, d in fp.blocks),
                predicted_bytes=float(ceiling_bytes),
                actual_bytes=float(fp.total_bytes),
                message=f"grid={fp.grid} blocks+scratch keep "
                        f"{fp.total_bytes:,} bytes resident per core "
                        f"(blocks {fp.block_bytes:,} + scratch "
                        f"{fp.scratch_bytes:,}) > VMEM ceiling "
                        f"{ceiling_bytes:,}"))


def r4_donation_audit(report: Report, hlo_text: str,
                      min_bytes: float = R4_MIN_BYTES,
                      donated_ok: Optional[Sequence[int]] = None) -> None:
    """Flag large non-donated entry params whose type reappears as output."""
    params, outs = entry_layout(hlo_text)
    if not params:
        return
    aliased = aliased_param_indices(hlo_text)
    out_keys = {type_key(o) for o in outs}
    for i, p in enumerate(params):
        if i in aliased or (donated_ok and i in donated_ok):
            continue
        b = type_bytes(p)
        if b >= min_bytes and type_key(p) in out_keys:
            report.add(Finding(
                "R4", Severity.WARN, "parameter", shape=p,
                actual_bytes=b,
                message=f"entry param {i} ({b:,.0f}B) is returned "
                        f"same-shaped but not donation-aliased — XLA "
                        f"copies it every step; donate it "
                        f"(Locale.jit(fn, donate=(...,)))"))
