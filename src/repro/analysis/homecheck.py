"""The homecheck orchestrator: trace, lower, extract facts, run R1-R11.

`check_workload` takes a `Locale` plus a registered workload name, builds
the jitted entry point exactly as a caller would (`Locale.workload`),
lowers it for a representative granular input, and runs every rule over
the resulting artifacts (optimized SPMD HLO + jaxpr + the engine's
exchange-network descriptor).  `check_decode` does the same for the
serving decode step.  Nothing is ever *executed* — the whole analysis is
static, so locality bugs surface at compile time, not in BENCH diffs.

`rules` filters which rules run (None/'all' = every rule); R1/R2 need HLO
facts, R3/R5/R7/R8 the jaxpr, R6 the (policy, mesh-slice) the shard_map
engine was built for, R9 a scheduler lattice (only the serving target has
one — other targets note the skip), R10/R11 just the compiled HLO (R10
additionally takes `compiled.memory_analysis()` when the caller has it).

Budget notes (R1):

  * The analytic budget is `engine.collective_census` — available only for
    the shard_map sort engine.  Backends without a byte model (the
    constraint tree, microbench, decode) skip R1 with a report note; their
    collectives are still screened by R2.
  * The entry point returns *logical* order.  For a non-localised
    hash-interleaved policy the engine's output is still the interleaved
    (chunk, m) view, so the jit epilogue un-interleaves it — one extra
    full-array all-gather that is part of the entry-point contract, not
    the engine schedule.  The orchestrator budgets it explicitly.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Sequence

from repro.analysis.findings import Report, normalize_rules
from repro.analysis.kernelcheck import (r5_block_coverage, r7_index_arith,
                                        r8_dead_lanes)
from repro.analysis.netverify import r6_network_certification
from repro.analysis.rules import (R4_MIN_BYTES, r1_surprise_collective,
                                  r2_home_leak, r3_vmem_budget,
                                  r4_donation_audit)
from repro.analysis.vmem import pallas_call_facts, pallas_footprints


def _mesh_axes(mesh):
    names = tuple(mesh.axis_names)
    return names, tuple(mesh.shape[a] for a in names)


def check_artifacts(target: str, hlo_text: str, *,
                    jaxpr=None,
                    predicted: Optional[Dict[str, Dict]] = None,
                    mesh=None,
                    allowed_axes: Sequence[str] = (),
                    vmem_ceiling: Optional[int] = None,
                    donation_min_bytes: float = R4_MIN_BYTES,
                    network=None,
                    sched_lattice=None,
                    hbm_ceiling: Optional[int] = None,
                    memory_stats=None,
                    context: Optional[Dict] = None,
                    rules=None,
                    suppress: Sequence[str] = ()) -> Report:
    """Run the selected rules over already-produced artifacts.

    `predicted=None` skips R1 (no analytic budget); `mesh=None` skips R2;
    `jaxpr=None` skips R3/R5/R7/R8; `network=None` (else a
    `(policy, sizes, axes)` triple for the shard_map engine) skips R6;
    `sched_lattice=None` skips R9 (else a sequence of
    `schedcheck.LatticeEntry`); R10 gates peak live bytes against
    `hbm_ceiling` (default `repro.kernels.HBM_BYTES_PER_DEVICE`), taking
    XLA's own `compiled.memory_analysis()` figures when passed as
    `memory_stats`; R11 needs only the HLO text.
    """
    from repro.kernels import HBM_BYTES_PER_DEVICE, VMEM_BYTES_PER_CORE
    from repro.launch.hlo_cost import analyze

    active = set(normalize_rules(rules))
    report = Report(target=target, context=dict(context or {}))
    facts = analyze(hlo_text)
    coll_ops = facts["collective_ops"]

    if "R1" in active:
        if predicted is not None:
            r1_surprise_collective(report, coll_ops, predicted)
        else:
            report.notes.append("R1 skipped: no analytic collective budget "
                                "for this target")
    if "R2" in active:
        if mesh is not None:
            names, sizes = _mesh_axes(mesh)
            r2_home_leak(report, coll_ops, names, sizes, allowed_axes)
        elif coll_ops:
            report.notes.append("R2 skipped: no mesh to map device groups "
                                "onto")
    if jaxpr is not None:
        if "R3" in active:
            r3_vmem_budget(report, pallas_footprints(jaxpr),
                           vmem_ceiling or VMEM_BYTES_PER_CORE)
        if active & {"R5", "R7", "R8"}:
            kfacts = pallas_call_facts(jaxpr)
            if "R5" in active:
                r5_block_coverage(report, kfacts)
            if "R7" in active:
                r7_index_arith(report, kfacts)
            if "R8" in active:
                r8_dead_lanes(report, kfacts)
    if "R4" in active:
        r4_donation_audit(report, hlo_text, min_bytes=donation_min_bytes)
    if "R6" in active:
        if network is not None:
            policy, sizes, axes = network
            r6_network_certification(report, policy, sizes, axes)
        else:
            report.notes.append("R6 skipped: target has no exchange "
                                "network (not the shard_map engine)")
    if "R9" in active:
        if sched_lattice is not None:
            from repro.analysis.schedcheck import r9_scheduler_certification
            r9_scheduler_certification(report, sched_lattice)
        else:
            report.notes.append("R9 skipped: target has no serving "
                                "scheduler (serve[decode] only)")
    if "R10" in active:
        from repro.analysis.livecheck import r10_hbm_live_range
        r10_hbm_live_range(report, hlo_text,
                           hbm_ceiling or HBM_BYTES_PER_DEVICE,
                           memory_stats=memory_stats)
    if "R11" in active:
        from repro.analysis.livecheck import r11_collective_control_flow
        r11_collective_control_flow(report, hlo_text)
    return report.suppress(suppress)


def _round_up(n: int, g: int) -> int:
    return (n + g - 1) // g * g


def check_workload(locale, workload: str = "sort", *,
                   backend: Optional[str] = None,
                   num_workers: Optional[int] = None,
                   local_phase: Optional[str] = None,
                   logn: int = 12, reps: int = 4,
                   vmem_ceiling: Optional[int] = None,
                   hbm_ceiling: Optional[int] = None,
                   rules=None,
                   suppress: Sequence[str] = ()) -> Report:
    """Statically check one registered workload under `locale`.

    Builds the workload exactly as `Locale.workload` would, lowers it for a
    granule-aligned int32 input of ~2**logn elements, and runs the selected
    rules (default all of R1-R8).
    """
    import jax
    import jax.numpy as jnp

    from repro.core.engine import collective_census, engine_granule
    from repro.core.homing import Homing, axis_tuple
    from repro.core.sort import constraint_granule

    mesh, policy = locale.mesh, locale.policy
    axes = axis_tuple(locale.axis)
    if mesh is not None:
        sort_sizes = tuple(mesh.shape[a] for a in axes)
    else:
        sort_sizes = (len(jax.devices()),)      # make_engine_fn's own mesh
    m = math.prod(sort_sizes)
    hash_homed = policy.homing == Homing.HASH_INTERLEAVED

    if workload in ("sort", "engine"):
        backend = backend or ("shard_map" if workload == "engine"
                              else "constraint")
        kw = dict(backend=backend, num_workers=num_workers,
                  local_phase=local_phase)
        if backend == "shard_map":
            granule = engine_granule(m, num_workers, hash_homed)
        else:
            granule = constraint_granule(mesh, policy, num_workers,
                                         locale.axis)
        fn = locale.workload(workload, **kw)
        n = _round_up(1 << logn, granule)
        predicted = None
        network = None
        if backend == "shard_map":
            network = (policy, sort_sizes,
                       axes if mesh is not None else None)
            predicted = collective_census(n, sort_sizes, policy,
                                          num_workers=num_workers,
                                          itemsize=4,
                                          local_phase=local_phase)
            if not policy.localised and hash_homed and m > 1:
                # the logical-order epilogue: un-interleaving the output
                # costs one more full-array gather (see module docstring)
                B = (n // m) * 4
                e = predicted.setdefault("all-gather",
                                         {"count": 0, "wire_bytes": 0.0})
                e["count"] += 1
                e["wire_bytes"] += (m - 1) * B
        context = dict(workload=workload, backend=backend,
                       policy=policy.name, n=n,
                       mesh=dict(zip(*_mesh_axes(mesh))) if mesh else None)
        target = f"{workload}[{backend}]"
    elif workload == "microbench":
        fn = locale.workload("microbench", reps=reps)
        n = _round_up(1 << logn, m)
        predicted = None
        network = None
        context = dict(workload="microbench", reps=reps, policy=policy.name,
                       n=n, mesh=dict(zip(*_mesh_axes(mesh))) if mesh else None)
        target = "microbench"
    else:
        raise ValueError(
            f"homecheck has no static driver for workload {workload!r}; "
            f"serving goes through check_decode")

    dtype = jnp.float32 if workload == "microbench" else jnp.int32
    x = jnp.arange(n, dtype=jnp.int32).astype(dtype)
    compiled = fn.lower(x).compile()
    hlo = compiled.as_text()
    traceable = getattr(fn, "__wrapped__", fn)
    jaxpr = jax.make_jaxpr(traceable)(x)
    return check_artifacts(target, hlo, jaxpr=jaxpr, predicted=predicted,
                           mesh=mesh, allowed_axes=axes,
                           vmem_ceiling=vmem_ceiling, network=network,
                           hbm_ceiling=hbm_ceiling,
                           memory_stats=_memory_stats(compiled),
                           context=context, rules=rules, suppress=suppress)


def _memory_stats(compiled):
    """`compiled.memory_analysis()`, None where the backend lacks it."""
    try:
        return compiled.memory_analysis()
    except Exception:
        return None


def check_decode(mesh=None, *, cfg_name: str = "qwen3-0.6b",
                 batch_slots: int = 4, max_len: int = 64,
                 prompt_len: int = 8,
                 hbm_ceiling: Optional[int] = None,
                 sched_lattice=None,
                 rules=None,
                 suppress: Sequence[str] = ()) -> Report:
    """Statically check the serving decode step (the `DecodeServer` jit).

    Builds a reduced-config server over `mesh` (None = single device),
    derives the KV-cache avals via `jax.eval_shape` on prefill (nothing
    runs), and lowers one decode step.  R2's declared axes are the plan's
    batch axes (slot homing) plus "model" (tensor parallelism) — any
    collective spanning another axis reshards homed cache state.

    R9 certifies the scheduler over `sched_lattice` (default the cheap
    `schedcheck.FAST_LATTICE` corner; the CLI runs the full
    `DEFAULT_LATTICE` once per invocation and prints the certificate).
    """
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, reduce_config
    from repro.configs.base import ShapeSpec
    from repro.models.model import LM
    from repro.runtime.server import DecodeServer
    from repro.sharding.partition import NULL_PLAN, make_plan

    cfg = reduce_config(get_config(cfg_name))
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    plan = (make_plan(mesh, cfg, ShapeSpec("d", max_len, batch_slots,
                                           "decode"))
            if mesh is not None else NULL_PLAN)
    srv = DecodeServer(cfg, params, batch_slots=batch_slots,
                       max_len=max_len, plan=plan)

    toks = jax.ShapeDtypeStruct((batch_slots, prompt_len), jnp.int32)
    _, caches = jax.eval_shape(
        lambda p, t: model.prefill(p, {"tokens": t}, plan, max_len=max_len),
        params, toks)
    batch = {"tokens": jax.ShapeDtypeStruct((batch_slots, 1), jnp.int32)}
    # per-slot position clocks: the continuous-batching decode step takes
    # a (batch_slots,) vector, each row at its own position
    pos = jnp.full((batch_slots,), prompt_len, jnp.int32)
    args = (params, caches, batch, pos)
    compiled = srv._decode.lower(*args).compile()
    hlo = compiled.as_text()
    jaxpr = jax.make_jaxpr(srv._decode)(*args)
    allowed = tuple(plan.batch_axes or ()) + ("model",)
    if sched_lattice is None:
        from repro.analysis.schedcheck import FAST_LATTICE
        sched_lattice = FAST_LATTICE
    context = dict(workload="serve", cfg=cfg_name, batch_slots=batch_slots,
                   max_len=max_len,
                   mesh=dict(zip(*_mesh_axes(mesh))) if mesh else None)
    return check_artifacts("serve[decode]", hlo, jaxpr=jaxpr,
                           predicted=None, mesh=mesh, allowed_axes=allowed,
                           sched_lattice=sched_lattice,
                           hbm_ceiling=hbm_ceiling,
                           memory_stats=_memory_stats(compiled),
                           context=context, rules=rules, suppress=suppress)
