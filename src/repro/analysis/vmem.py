"""Static VMEM footprints of every `pallas_call` in a traced program.

Rule R3's fact extractor: walks a (closed) jaxpr recursively — through
pjit, scan/while bodies, cond branches, shard_map, custom-derivative
wrappers — and for each `pallas_call` equation computes the bytes the call
keeps resident per grid step: one block per operand/result BlockSpec plus
every scratch operand, straight from the grid mapping.  This is exactly
what the kernel allocates on-chip, so comparing it to the per-core VMEM
ceiling catches oversized chunks at lowering time instead of as a runtime
crash (or a silent spill) at production sizes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List

import numpy as np


@dataclass(frozen=True)
class PallasFootprint:
    name: str                    # kernel name (debug info) or "pallas_call"
    grid: tuple
    block_bytes: int             # sum over in/out BlockSpec blocks
    scratch_bytes: int           # sum over scratch shapes (VMEM/SMEM)
    blocks: tuple                # ((shape, dtype_str), ...) for the message

    @property
    def total_bytes(self) -> int:
        return self.block_bytes + self.scratch_bytes


def _block_numel(block_shape) -> int:
    n = 1
    for d in block_shape:
        if d is None:            # squeezed dim
            continue
        n *= int(getattr(d, "block_size", d))   # plain int or Blocked dim
    return n


def _aval_bytes(aval) -> int:
    shape = tuple(getattr(aval, "shape", ()) or ())
    dtype = np.dtype(getattr(aval, "dtype", np.float32))
    return int(np.prod(shape, dtype=np.int64)) * dtype.itemsize if shape \
        else dtype.itemsize


def pallas_footprints(jaxpr_like: Any) -> List[PallasFootprint]:
    """All pallas_call footprints reachable from a jaxpr or ClosedJaxpr."""
    out: List[PallasFootprint] = []
    seen = set()

    def sub_jaxprs(value):
        if hasattr(value, "eqns"):                   # Jaxpr
            yield value
        elif hasattr(value, "jaxpr") and hasattr(value.jaxpr, "eqns"):
            yield value.jaxpr                        # ClosedJaxpr
        elif isinstance(value, (list, tuple)):
            for v in value:
                yield from sub_jaxprs(v)

    def visit(jaxpr):
        if id(jaxpr) in seen:
            return
        seen.add(id(jaxpr))
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "pallas_call":
                out.append(_footprint(eqn))
            for v in eqn.params.values():
                for sub in sub_jaxprs(v):
                    visit(sub)

    for j in sub_jaxprs(jaxpr_like):
        visit(j)
    return out


def _footprint(eqn) -> PallasFootprint:
    gm = eqn.params["grid_mapping"]
    blocks = []
    block_bytes = 0
    for bm in gm.block_mappings:
        numel = _block_numel(bm.block_shape)
        dtype = np.dtype(bm.array_shape_dtype.dtype)
        block_bytes += numel * dtype.itemsize
        blocks.append((tuple(d if d is None else int(getattr(d, "block_size",
                                                             d))
                             for d in bm.block_shape), str(dtype)))
    scratch_bytes = 0
    n_scratch = getattr(gm, "num_scratch_operands", 0)
    if n_scratch:
        kernel_jaxpr = eqn.params.get("jaxpr")
        if kernel_jaxpr is not None:
            for var in kernel_jaxpr.invars[-n_scratch:]:
                scratch_bytes += _aval_bytes(var.aval)
    name = getattr(getattr(eqn.params.get("debug"), "func_name", None),
                   "__str__", lambda: "")() or \
        str(eqn.params.get("name", "")) or "pallas_call"
    return PallasFootprint(name=name, grid=tuple(gm.grid),
                           block_bytes=block_bytes,
                           scratch_bytes=scratch_bytes,
                           blocks=tuple(blocks))
