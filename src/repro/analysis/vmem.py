"""Static facts about every `pallas_call` in a traced program.

Two fact extractors share one recursive jaxpr walker (through pjit,
scan/while bodies, cond branches, shard_map, custom-derivative wrappers):

* `pallas_footprints` — rule R3's view: the bytes each call keeps resident
  per grid step (one block per operand/result BlockSpec plus every scratch
  operand), compared against the per-core VMEM ceiling so oversized chunks
  fail at lowering time instead of as a runtime crash at production sizes.
* `pallas_call_facts` — rules R5/R7/R8's view: the full grid, every
  operand's array/block shapes and a *callable* index map (the BlockSpec's
  `index_map_jaxpr` evaluated concretely per grid point), and the kernel
  jaxpr itself — enough to statically replay the block schedule and the
  kernel's predicate structure without executing anything.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, List, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class PallasFootprint:
    name: str                    # kernel name (debug info) or "pallas_call"
    grid: tuple
    block_bytes: int             # sum over in/out BlockSpec blocks
    scratch_bytes: int           # sum over scratch shapes (VMEM/SMEM)
    blocks: tuple                # ((shape, dtype_str), ...) for the message

    @property
    def total_bytes(self) -> int:
        return self.block_bytes + self.scratch_bytes


def _block_numel(block_shape) -> int:
    n = 1
    for d in block_shape:
        if d is None:            # squeezed dim
            continue
        n *= int(getattr(d, "block_size", d))   # plain int or Blocked dim
    return n


def _aval_bytes(aval) -> int:
    shape = tuple(getattr(aval, "shape", ()) or ())
    dtype = np.dtype(getattr(aval, "dtype", np.float32))
    return int(np.prod(shape, dtype=np.int64)) * dtype.itemsize if shape \
        else dtype.itemsize


def _sub_jaxprs(value):
    if hasattr(value, "eqns"):                   # Jaxpr
        yield value
    elif hasattr(value, "jaxpr") and hasattr(value.jaxpr, "eqns"):
        yield value.jaxpr                        # ClosedJaxpr
    elif isinstance(value, (list, tuple)):
        for v in value:
            yield from _sub_jaxprs(v)


def _walk_pallas_calls(jaxpr_like: Any, on_eqn: Callable[[Any], None]) -> None:
    """Call `on_eqn` on every pallas_call eqn reachable from `jaxpr_like`."""
    seen = set()

    def visit(jaxpr):
        if id(jaxpr) in seen:
            return
        seen.add(id(jaxpr))
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "pallas_call":
                on_eqn(eqn)
            for v in eqn.params.values():
                for sub in _sub_jaxprs(v):
                    visit(sub)

    for j in _sub_jaxprs(jaxpr_like):
        visit(j)


def pallas_footprints(jaxpr_like: Any) -> List[PallasFootprint]:
    """All pallas_call footprints reachable from a jaxpr or ClosedJaxpr."""
    out: List[PallasFootprint] = []
    _walk_pallas_calls(jaxpr_like, lambda eqn: out.append(_footprint(eqn)))
    return out


def _footprint(eqn) -> PallasFootprint:
    gm = eqn.params["grid_mapping"]
    blocks = []
    block_bytes = 0
    for bm in gm.block_mappings:
        numel = _block_numel(bm.block_shape)
        dtype = np.dtype(bm.array_shape_dtype.dtype)
        block_bytes += numel * dtype.itemsize
        blocks.append((tuple(d if d is None else int(getattr(d, "block_size",
                                                             d))
                             for d in bm.block_shape), str(dtype)))
    scratch_bytes = 0
    n_scratch = getattr(gm, "num_scratch_operands", 0)
    if n_scratch:
        kernel_jaxpr = eqn.params.get("jaxpr")
        if kernel_jaxpr is not None:
            for var in kernel_jaxpr.invars[-n_scratch:]:
                scratch_bytes += _aval_bytes(var.aval)
    name = getattr(getattr(eqn.params.get("debug"), "func_name", None),
                   "__str__", lambda: "")() or \
        str(eqn.params.get("name", "")) or "pallas_call"
    return PallasFootprint(name=name, grid=tuple(gm.grid),
                           block_bytes=block_bytes,
                           scratch_bytes=scratch_bytes,
                           blocks=tuple(blocks))


# ---------------------------------------------------------------------------
# R5/R7/R8 facts: block schedules and kernel jaxprs
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class OperandFacts:
    """One input or output BlockSpec, with its index map made callable."""
    role: str                        # "in" | "out"
    array_shape: Tuple[int, ...]
    dtype: str
    block_shape: Tuple[Optional[int], ...]   # None = squeezed dim
    index_map: Callable              # grid indices -> block indices

    @property
    def full_block(self) -> Tuple[int, ...]:
        """Block shape with squeezed dims restored as size 1."""
        return tuple(1 if d is None else d for d in self.block_shape)


@dataclass(frozen=True)
class PallasCallFacts:
    name: str
    grid: Tuple[int, ...]
    inputs: Tuple[OperandFacts, ...]
    outputs: Tuple[OperandFacts, ...]
    kernel_jaxpr: Any                # the kernel body (a Jaxpr), or None
    static_grid: bool                # False when any grid bound is dynamic


def _index_map_fn(bm) -> Callable:
    """The BlockSpec's index_map as a concrete python callable."""
    import jax
    cj = bm.index_map_jaxpr

    def index_map(*grid_idx):
        outs = jax.core.eval_jaxpr(cj.jaxpr, cj.consts, *grid_idx)
        return tuple(int(o) for o in outs)

    return index_map


def pallas_call_facts(jaxpr_like: Any) -> List[PallasCallFacts]:
    """Grid/block/kernel facts for every reachable pallas_call."""
    out: List[PallasCallFacts] = []

    def on_eqn(eqn):
        gm = eqn.params["grid_mapping"]
        n_in = int(getattr(gm, "num_inputs", 0))
        grid = tuple(gm.grid)
        static = (getattr(gm, "num_dynamic_grid_bounds", 0) == 0
                  and all(isinstance(g, (int, np.integer)) for g in grid))
        ops: List[OperandFacts] = []
        for k, bm in enumerate(gm.block_mappings):
            sd = bm.array_shape_dtype
            block = tuple(
                None if d is None else int(getattr(d, "block_size", d))
                for d in bm.block_shape)
            ops.append(OperandFacts(
                role="in" if k < n_in else "out",
                array_shape=tuple(int(s) for s in sd.shape),
                dtype=str(np.dtype(sd.dtype)),
                block_shape=block,
                index_map=_index_map_fn(bm)))
        nsi = eqn.params.get("name_and_src_info")
        name = getattr(nsi, "name", None) or str(nsi or "") or "pallas_call"
        out.append(PallasCallFacts(
            name=name, grid=grid,
            inputs=tuple(o for o in ops if o.role == "in"),
            outputs=tuple(o for o in ops if o.role == "out"),
            kernel_jaxpr=eqn.params.get("jaxpr"),
            static_grid=static))

    _walk_pallas_calls(jaxpr_like, on_eqn)
    return out
