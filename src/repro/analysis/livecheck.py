"""R10/R11: compiled-module memory and control-flow rules.

Two rules over the optimized (post-SPMD) HLO of a lowered workload — the
compiled artifact, not the source program:

  R10 hbm-live-range          Gate the per-device peak live HBM bytes
                              against a declared ceiling.  The peak is the
                              max of (a) the text-level linear-scan
                              liveness of `hlo_facts.liveness` and (b) the
                              authoritative XLA figures when the caller
                              passes `compiled.memory_analysis()` —
                              argument + output + temp − aliased.  On
                              success the finding is a note that also
                              reports the headroom, which is exactly the
                              budget the KV prefix pools of the serving
                              scheduler can grow into.

  R11 collective-control-flow Flag collectives whose execution depends on
                              data-dependent control flow.  A `conditional`
                              whose branches carry *different* collective
                              sequences (kind + payload bytes, recursively
                              through the call graph) is an ERROR: under
                              today's single-controller emulation every
                              device takes the same branch so it is benign,
                              but the moment the ROADMAP's multi-process
                              item lands, devices disagreeing on the branch
                              deadlock on the first mismatched collective.
                              A `while` loop without a compiler-proven
                              `known_trip_count` that contains collectives
                              is a WARN for the same reason: the loop count
                              itself becomes data the processes must agree
                              on.  Identical sequences on every branch are
                              fine — the collective happens either way.

Both rules parse with `launch.hlo_cost.parse_module`; neither needs the
jaxpr or the exchange schedule, so they run on any HLO text (including the
committed known-bad fixtures in `analysis.fixtures`).
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

from repro.analysis.findings import Finding, Report, Severity
from repro.analysis.hlo_facts import liveness
from repro.launch.hlo_cost import (_BRANCH_RE, _CALLS_RE, _COND_BODY_RE,
                                   _TO_APPLY_RE, _dedupe_async, _shape_bytes,
                                   parse_module)

_TRUE_FALSE_RE = re.compile(
    r"true_computation=%?([\w.\-]+),\s*false_computation=%?([\w.\-]+)")
_NAME_RE = re.compile(r"%([\w.\-]+)")


def _mem_stats_peak(memory_stats) -> Optional[float]:
    """argument + output + temp − aliased, from a dict or a
    `compiled.memory_analysis()` object; None when unavailable."""
    if memory_stats is None:
        return None

    def get(key: str) -> Optional[float]:
        if isinstance(memory_stats, dict):
            v = memory_stats.get(key, memory_stats.get(key + "_size_in_bytes"))
        else:
            v = getattr(memory_stats, key + "_size_in_bytes", None)
        return float(v) if v is not None else None

    arg, out, temp = get("argument"), get("output"), get("temp")
    if arg is None and out is None and temp is None:
        return None
    alias = get("alias") or 0.0
    return (arg or 0.0) + (out or 0.0) + (temp or 0.0) - alias


def r10_hbm_live_range(report: Report, hlo_text: str, ceiling: float,
                       memory_stats=None) -> None:
    """Gate peak live HBM bytes of the compiled module against `ceiling`."""
    live = liveness(hlo_text)
    scan_peak = live["peak_bytes"]
    stats_peak = _mem_stats_peak(memory_stats)
    peak = max(scan_peak, stats_peak or 0.0)
    source = ("xla memory_analysis" if stats_peak is not None
              and stats_peak >= scan_peak else "liveness scan")
    if peak > ceiling:
        top = ", ".join(f"{name}:{opcode}={b:,.0f}B"
                        for b, name, opcode in live["live_at_peak"][:4])
        report.add(Finding(
            rule="R10", severity=Severity.ERROR, op="module",
            predicted_bytes=ceiling, actual_bytes=peak,
            message=f"peak live HBM {peak:,.0f}B exceeds the "
                    f"{ceiling:,.0f}B per-device ceiling ({source}; "
                    f"largest at peak: {top})"))
        return
    headroom = ceiling - peak
    report.notes.append(
        f"R10: hbm-live-range ok — peak {peak:,.0f}B of {ceiling:,.0f}B "
        f"ceiling ({source}; {headroom:,.0f}B headroom for KV pools, "
        f"{live['n_buffers']} buffers scanned)")


def _callees(op) -> List[str]:
    """Computation names an op transfers control to (all kinds)."""
    names: List[str] = []
    if op.opcode == "while":
        m = _COND_BODY_RE.search(op.line)
        if m:
            names.append(m.group(2))
    elif op.opcode == "conditional":
        m = _BRANCH_RE.search(op.line)
        if m:
            names.extend(_NAME_RE.findall(m.group(1)))
        else:
            m = _TRUE_FALSE_RE.search(op.line)
            if m:
                names.extend([m.group(1), m.group(2)])
    else:
        m = _TO_APPLY_RE.search(op.line) or _CALLS_RE.search(op.line)
        if m:
            names.append(m.group(1))
    return names


def collective_signature(comps: Dict, name: str,
                         depth: int = 0) -> Tuple[Tuple[str, int], ...]:
    """Ordered (kind, payload bytes) sequence of every collective reachable
    from computation `name`, recursing through whiles/calls/fusions and —
    for nested conditionals — through every branch (a nested mismatch is
    caught when that conditional is itself visited)."""
    comp = comps.get(name)
    if comp is None or depth > 50:
        return ()
    sig: List[Tuple[str, int]] = []
    for op in comp.ops:
        kind = _dedupe_async(op)
        if kind:
            sig.append((kind, int(_shape_bytes(op.result))))
        for callee in _callees(op):
            sig.extend(collective_signature(comps, callee, depth + 1))
    return tuple(sig)


def _branch_names(op) -> List[str]:
    m = _BRANCH_RE.search(op.line)
    if m:
        return _NAME_RE.findall(m.group(1))
    m = _TRUE_FALSE_RE.search(op.line)
    return [m.group(1), m.group(2)] if m else []


def r11_collective_control_flow(report: Report, hlo_text: str) -> None:
    """Flag collectives under data-dependent control flow."""
    comps = parse_module(hlo_text)
    n_cond = n_while = 0
    for cname, comp in comps.items():
        if cname == "__entry__":        # alias of the entry computation
            continue
        for op in comp.ops:
            if op.opcode == "conditional":
                n_cond += 1
                branches = _branch_names(op)
                sigs = [collective_signature(comps, b) for b in branches]
                if sigs and any(s != sigs[0] for s in sigs[1:]):
                    detail = "; ".join(
                        f"branch {i} [{b}]: "
                        + (", ".join(f"{k}:{by:,d}B" for k, by in s) or "none")
                        for i, (b, s) in enumerate(zip(branches, sigs)))
                    report.add(Finding(
                        rule="R11", severity=Severity.ERROR, op="conditional",
                        shape=op.result,
                        message=f"collective sequences differ across "
                                f"branches of %{op.name} in %{cname} — "
                                f"devices disagreeing on the predicate "
                                f"deadlock under multi-process ({detail})"))
            elif op.opcode == "while":
                n_while += 1
                m = _COND_BODY_RE.search(op.line)
                if m and "known_trip_count" not in op.line:
                    body_sig = collective_signature(comps, m.group(2))
                    if body_sig:
                        kinds = ", ".join(sorted({k for k, _ in body_sig}))
                        report.add(Finding(
                            rule="R11", severity=Severity.WARN, op="while",
                            shape=op.result,
                            message=f"%{op.name} in %{cname} has no "
                                    f"compiler-proven trip count but its "
                                    f"body issues collectives ({kinds}) — "
                                    f"the iteration count is data the "
                                    f"processes must agree on"))
    if not any(f.rule == "R11" for f in report.findings):
        report.notes.append(
            f"R11: collective-control-flow ok — {n_cond} conditional(s) and "
            f"{n_while} while loop(s) scanned, every reachable collective "
            f"is control-independent")
