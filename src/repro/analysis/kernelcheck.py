"""Rules R5/R7/R8: static checks on every `pallas_call`'s block schedule.

Facts come from `vmem.pallas_call_facts` (grid, per-operand BlockSpecs with
concretely evaluable index maps, the kernel jaxpr); nothing here traces or
executes a kernel.

  R5 write-race/coverage — replay every output index map over the full
      grid.  Grid dims the block index does not depend on are *revisit*
      dims (sequential accumulation, e.g. flash-attention's KV loop) and
      are fine; two grid steps that differ in a dim the index DOES depend
      on yet land on the same output block are a write race (ERROR —
      last-writer-wins nondeterminism across cores).  A block cell of the
      output no grid step ever writes is a gap (WARN — uninitialised
      output).  Input blocks whose start lies fully outside the array are
      reads of nothing but clamp padding (ERROR).
  R7 index-arithmetic/sentinel — merge-path rank arithmetic runs in the
      index dtype; a block whose merged domain (2 x block elements)
      exceeds int32 overflows ranks exactly at production chunk sizes
      (ERROR).  The BIG sentinel (`core.sort.pad_value`) must cast into
      the key dtype without clipping and compare strictly-after every
      finite key (nothing real may tie with padding): a clipped cast is
      an ERROR, a finite-max sentinel that ties is a WARN.
  R8 grid-dead-lane — a `pl.when` predicate comparing `program_id(axis)`
      against a constant that no value in [0, grid[axis]) satisfies is a
      lane that never executes: the grid step is scheduled, occupies a
      core, and does nothing (WARN — wasted cores, usually a stale grid
      constant).
"""
from __future__ import annotations

import itertools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis.findings import Finding, Report, Severity
from repro.analysis.vmem import OperandFacts, PallasCallFacts

#: R5 replays index maps concretely; cap the enumeration.
MAX_GRID_POINTS = 65536

#: R8 evaluates predicates over a grid axis; cap the domain.
MAX_AXIS_DOMAIN = 1 << 20


def _grid_points(grid: Sequence[int]):
    return itertools.product(*(range(int(g)) for g in grid))


def _dependent_dims(mapping: Dict[Tuple[int, ...], Tuple[int, ...]],
                    grid: Sequence[int]) -> List[int]:
    """Grid dims whose value ever changes the block index.

    Exact, not sampled: dim d is independent iff the map is constant on
    every fibre {points equal outside d} — checked by grouping on the
    point with coordinate d zeroed.
    """
    deps = []
    for d in range(len(grid)):
        groups: Dict[Tuple[int, ...], Tuple[int, ...]] = {}
        dependent = False
        for pt, idx in mapping.items():
            key = pt[:d] + (0,) + pt[d + 1:]
            if groups.setdefault(key, idx) != idx:
                dependent = True
                break
        if dependent:
            deps.append(d)
    return deps


def r5_block_coverage(report: Report, facts: List[PallasCallFacts]) -> None:
    """Prove each output's block images partition it; bound input reads."""
    for fc in facts:
        if not fc.static_grid:
            report.notes.append(
                f"R5 skipped for {fc.name}: dynamic grid bounds")
            continue
        npts = int(np.prod([int(g) for g in fc.grid], dtype=np.int64)) \
            if fc.grid else 1
        if npts > MAX_GRID_POINTS:
            report.notes.append(
                f"R5 skipped for {fc.name}: grid {fc.grid} has {npts:,} "
                f"steps (> {MAX_GRID_POINTS:,})")
            continue
        for k, op in enumerate(fc.outputs):
            _check_output(report, fc, k, op)
        for k, op in enumerate(fc.inputs):
            _check_input(report, fc, k, op)


def _check_output(report: Report, fc: PallasCallFacts, k: int,
                  op: OperandFacts) -> None:
    mapping = {pt: op.index_map(*pt) for pt in _grid_points(fc.grid)}
    deps = _dependent_dims(mapping, fc.grid)

    # write race: same block from two assignments of the dependent dims
    first: Dict[Tuple, Tuple] = {}
    for pt, idx in mapping.items():
        dep_pt = tuple(pt[d] for d in deps)
        prev = first.setdefault(idx, dep_pt)
        if prev != dep_pt:
            report.add(Finding(
                "R5", Severity.ERROR, "pallas_call", shape=fc.name,
                message=f"write race on output {k}: grid steps "
                        f"{tuple(fc.grid)}-indexed at dependent dims "
                        f"{deps} values {prev} and {dep_pt} both write "
                        f"block {idx} — overlapping writes race across "
                        f"cores (non-dependent dims would be legitimate "
                        f"sequential revisits)"))
            break

    # coverage: every aligned block cell of the output must be written
    block = op.full_block
    need = [max(1, -(-a // b)) for a, b in zip(op.array_shape, block)]
    written = set(mapping.values())
    for cell in itertools.product(*(range(n) for n in need)):
        if cell not in written:
            report.add(Finding(
                "R5", Severity.WARN, "pallas_call", shape=fc.name,
                message=f"coverage gap on output {k}: block cell {cell} "
                        f"of {tuple(need)} (array {op.array_shape}, "
                        f"block {block}) is never written — that region "
                        f"of the output is uninitialised"))
            break


def _check_input(report: Report, fc: PallasCallFacts, k: int,
                 op: OperandFacts) -> None:
    block = op.full_block
    for pt in _grid_points(fc.grid):
        idx = op.index_map(*pt)
        for d, (i, b, a) in enumerate(zip(idx, block, op.array_shape)):
            if i < 0 or i * b >= max(a, 1):
                report.add(Finding(
                    "R5", Severity.ERROR, "pallas_call", shape=fc.name,
                    message=f"out-of-bounds read on input {k}: grid step "
                            f"{pt} maps dim {d} to block {i} (block size "
                            f"{b}, array extent {a}) — the block starts "
                            f"entirely outside the array"))
                return


def r7_index_arith(report: Report, facts: List[PallasCallFacts],
                   index_dtype: str = "int32",
                   sentinel: Optional[Any] = None) -> None:
    """Rank-domain overflow + BIG-sentinel safety per pallas_call.

    `sentinel` overrides the repo's `pad_value` (fixture hook); by default
    each key dtype is checked against what the engine actually pads with.
    """
    from repro.core.sort import pad_value
    imax = np.iinfo(np.dtype(index_dtype)).max
    for fc in facts:
        for k, op in enumerate(fc.inputs + fc.outputs):
            numel = int(np.prod(op.full_block, dtype=np.int64))
            if 2 * numel > imax:
                report.add(Finding(
                    "R7", Severity.ERROR, "pallas_call", shape=fc.name,
                    actual_bytes=float(2 * numel),
                    predicted_bytes=float(imax),
                    message=f"operand {k} block {op.full_block} merges "
                            f"2x{numel:,} elements but merge-path ranks "
                            f"are {index_dtype} (max {imax:,}) — rank "
                            f"arithmetic overflows at this chunk size"))
        for dt in sorted({op.dtype for op in fc.inputs + fc.outputs}):
            dtype = np.dtype(dt)
            if dtype.kind not in "fiu":
                continue
            big = sentinel if sentinel is not None else pad_value(dtype)
            _check_sentinel(report, fc.name, dtype, big)


def _check_sentinel(report: Report, name: str, dtype: np.dtype,
                    big: Any) -> None:
    with np.errstate(over="ignore", invalid="ignore"):
        lowered = np.asarray(big).astype(dtype)
    if dtype.kind == "f":
        limit = np.inf
        clipped = (np.isfinite(big) and
                   (np.isinf(lowered) or float(lowered) != float(big)))
    else:
        limit = np.iinfo(dtype).max
        clipped = int(lowered) != int(big)
    if clipped:
        report.add(Finding(
            "R7", Severity.ERROR, "pallas_call", shape=name,
            message=f"sentinel {big!r} is not representable in key dtype "
                    f"{dtype.name} (lowers to {lowered}) — padding would "
                    f"corrupt real keys"))
    elif dtype.kind == "f" and not np.isinf(lowered):
        report.add(Finding(
            "R7", Severity.WARN, "pallas_call", shape=name,
            message=f"sentinel {big!r} is finite in {dtype.name}: real "
                    f"keys equal to it tie with padding and can be "
                    f"dropped by the merge-split keep rule — use inf"))
    elif dtype.kind in "iu" and int(lowered) != int(limit):
        report.add(Finding(
            "R7", Severity.WARN, "pallas_call", shape=name,
            message=f"sentinel {big!r} is below {dtype.name} max "
                    f"({limit}): keys in ({big!r}, {limit}] sort after "
                    f"padding and leak into the kept halves"))


# ---------------------------------------------------------------------------
# R8: dead predicated lanes
# ---------------------------------------------------------------------------
_CMP = {"eq": np.equal, "ne": np.not_equal, "lt": np.less, "le": np.less_equal,
        "gt": np.greater, "ge": np.greater_equal}


def _literal_val(v) -> Optional[float]:
    val = getattr(v, "val", None)
    if val is None:
        return None
    arr = np.asarray(val)
    return float(arr) if arr.ndim == 0 else None


def _dead_predicates(kernel_jaxpr, grid) -> List[str]:
    """Messages for program_id comparisons no grid value satisfies."""
    pid_axis: Dict[Any, int] = {}       # var -> grid axis
    dead: List[str] = []
    for eqn in kernel_jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "program_id":
            pid_axis[eqn.outvars[0]] = int(eqn.params["axis"])
        elif (prim == "convert_element_type"
              and not hasattr(eqn.invars[0], "val")
              and eqn.invars[0] in pid_axis):
            pid_axis[eqn.outvars[0]] = pid_axis[eqn.invars[0]]
        elif prim in _CMP and len(eqn.invars) == 2:
            for a, b, flip in ((eqn.invars[0], eqn.invars[1], False),
                               (eqn.invars[1], eqn.invars[0], True)):
                axis = None if hasattr(a, "val") else pid_axis.get(a)
                lit = _literal_val(b)
                if axis is None or lit is None or axis >= len(grid):
                    continue
                dom = min(int(grid[axis]), MAX_AXIS_DOMAIN)
                ids = np.arange(dom)
                sat = (_CMP[prim](lit, ids) if flip
                       else _CMP[prim](ids, lit))
                if not bool(np.any(sat)):
                    dead.append(
                        f"predicate program_id({axis}) {prim} {lit:g} is "
                        f"false for every grid index in [0, {grid[axis]})")
                break
    return dead


def r8_dead_lanes(report: Report, facts: List[PallasCallFacts]) -> None:
    """Flag predicated lanes that provably never execute."""
    for fc in facts:
        if fc.kernel_jaxpr is None or not fc.static_grid:
            continue
        for msg in _dead_predicates(fc.kernel_jaxpr, fc.grid):
            report.add(Finding(
                "R8", Severity.WARN, "pallas_call", shape=fc.name,
                message=f"dead lane: {msg} — the guarded block never "
                        f"runs on any core (stale grid constant?)"))
