"""Typed findings for the homecheck static locality analyzer.

A `Finding` is one violation of the cache-home contract, tagged with the
rule that produced it (R1-R11), a severity, the offending op, and the
predicted-vs-actual byte counts where the rule is quantitative.  A `Report`
bundles the findings of one analyzed program together with the context
(workload, policy, mesh) they were produced under; ``report.clean`` is the
CI contract — no findings at WARN severity or above.
"""
from __future__ import annotations

import enum
import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


class Severity(enum.IntEnum):
    """Ordered: higher is worse.  ERROR fails CI (CLI exit 1)."""
    INFO = 0
    WARN = 1
    ERROR = 2


RULES = {
    "R1": "surprise-collective: HLO collective not budgeted by "
          "exchange_schedule (kind/count/bytes diff)",
    "R2": "home-leak: collective device groups vary over a mesh axis the "
          "locale never declared (GSPMD resharding of homed values)",
    "R3": "vmem-budget: pallas_call block+scratch footprint exceeds the "
          "per-core VMEM ceiling",
    "R4": "donation-audit: large step-carried buffer copied across steps "
          "(an output with the exact shape of a non-aliased input)",
    "R5": "write-race/coverage: pallas_call output index_maps must "
          "partition the output over the grid (overlap = race ERROR, "
          "gap = WARN) and input blocks must stay in bounds",
    "R6": "network-certification: the engine's exchange network is a "
          "structurally sound sorting network, 0-1-certified on every "
          "supported mesh up to 16 devices",
    "R7": "index-arithmetic: merge-path ranks must fit the index dtype at "
          "declared block sizes; the BIG sentinel must be representable "
          "and tie-stable in the key dtype",
    "R8": "grid-dead-lane: pl.when predicates on program_id that no grid "
          "index satisfies (scheduled cores that never execute)",
    "R9": "scheduler-certification: the serving scheduler's pure "
          "transitions exhaustively certified (I1-I7: off-home moves "
          "charged, starvation <= max_skip, work conservation, eviction "
          "never migrates, no double-booking, charges == replayed moves, "
          "minimal spill donor) over the small-config lattice",
    "R10": "hbm-live-range: the compiled module's per-device peak live "
           "HBM bytes exceed the declared ceiling "
           "(repro.kernels.HBM_BYTES_PER_DEVICE)",
    "R11": "collective-control-flow: a collective reachable only under "
           "data-dependent control flow, or with inconsistent per-branch "
           "ordering — deadlock once multi-process lands",
}


def normalize_rules(rules) -> Tuple[str, ...]:
    """Resolve a rule filter (None / 'all' / ids) to canonical rule ids."""
    if rules is None or rules == "all" or "all" in tuple(rules):
        return tuple(RULES)
    out = []
    for r in ([rules] if isinstance(rules, str) else rules):
        for part in str(r).replace(",", " ").split():
            rid = part.upper()
            if rid not in RULES:
                raise ValueError(f"unknown rule {part!r}; "
                                 f"known: {', '.join(RULES)}")
            if rid not in out:
                out.append(rid)
    return tuple(out)


@dataclass(frozen=True)
class Finding:
    rule: str                       # "R1".."R11"
    severity: Severity
    op: str                         # HLO opcode / primitive name
    shape: str = ""                 # offending value's type string
    predicted_bytes: Optional[float] = None
    actual_bytes: Optional[float] = None
    message: str = ""

    def format(self) -> str:
        pa = ""
        if self.predicted_bytes is not None or self.actual_bytes is not None:
            fmt = lambda b: "-" if b is None else f"{b:,.0f}B"
            pa = (f" predicted={fmt(self.predicted_bytes)}"
                  f" actual={fmt(self.actual_bytes)}")
        shape = f" {self.shape}" if self.shape else ""
        return (f"[{self.rule} {self.severity.name}] {self.op}{shape}{pa}"
                f" — {self.message}")


@dataclass
class Report:
    """Findings of one homecheck run plus the context they apply to."""
    target: str                                  # e.g. "sort[shard_map]"
    context: Dict = field(default_factory=dict)  # policy/mesh/n/backend...
    findings: List[Finding] = field(default_factory=list)
    suppressed: List[str] = field(default_factory=list)   # rule ids filtered
    notes: List[str] = field(default_factory=list)        # e.g. "R1 skipped"

    def add(self, finding: Finding) -> None:
        self.findings.append(finding)

    @property
    def clean(self) -> bool:
        """No findings at WARN or above — the CI-gating predicate."""
        return not any(f.severity >= Severity.WARN for f in self.findings)

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == Severity.ERROR]

    def suppress(self, rules: Sequence[str]) -> "Report":
        """Drop findings of the given rule ids (recorded in `suppressed`)."""
        rules = tuple(rules or ())
        if not rules:
            return self
        kept = [f for f in self.findings if f.rule not in rules]
        dropped = sorted({f.rule for f in self.findings if f.rule in rules})
        self.findings = kept
        self.suppressed.extend(dropped)
        return self

    def format(self, verbose: bool = False) -> str:
        head = f"homecheck {self.target}"
        ctx = ", ".join(f"{k}={v}" for k, v in self.context.items())
        if ctx:
            head += f" ({ctx})"
        lines = [head]
        for f in sorted(self.findings, key=lambda f: -f.severity):
            lines.append("  " + f.format())
        for n in self.notes if verbose else []:
            lines.append(f"  note: {n}")
        if self.suppressed:
            lines.append(f"  suppressed rules: {', '.join(self.suppressed)}")
        if not self.findings:
            lines.append("  clean: no findings")
        return "\n".join(lines)

    def to_json(self) -> str:
        return json.dumps({
            "target": self.target, "context": self.context,
            "clean": self.clean, "suppressed": self.suppressed,
            "notes": self.notes,
            "findings": [{
                "rule": f.rule, "severity": f.severity.name, "op": f.op,
                "shape": f.shape, "predicted_bytes": f.predicted_bytes,
                "actual_bytes": f.actual_bytes, "message": f.message,
            } for f in self.findings]}, indent=2)


def summarize(reports: Sequence[Report]) -> Tuple[int, int]:
    """(#reports with any finding, #ERROR findings) across a sweep."""
    dirty = sum(1 for r in reports if r.findings)
    errors = sum(len(r.errors) for r in reports)
    return dirty, errors
