"""Rule R6: certify the engine's exchange network as a sorting network.

The merge-split network is the one piece of the engine whose correctness is
*combinatorial*: a wrong permutation, a dropped substage, or a flipped keep
flag produces silently mis-sorted output on exactly the mesh shapes nobody
benchmarked — the same class of silent corruption coherence-protocol
verification targets in distributed directories.  `core.engine` now exposes
the network as data (`exchange_network`), so this module proves it instead
of sampling it:

Structural checks (any mesh size)
  * every substage's device-space `partner` map is a fixed-point-free
    involution at XOR stride 2^substage — neighbour-only traffic, nobody
    paired twice or with themselves;
  * keep flags are complementary across each pair (one side keeps the low
    half, the other the high half — anything else loses or duplicates a
    chunk);
  * every on-axis ppermute `perm` is a bijection of the declared axis and
    routes exactly the partner map's stride;
  * hierarchical plans never cross pods with a pairwise exchange: every
    `NetExchange` stride stays below the inner-axis size, cross-pod strides
    appear only as `NetGatherReplay` replays over the pod axes;
  * the (stage, substage) sequence is exactly the bitonic schedule
    ``stage i: substages i..0`` — no level missing, none duplicated.

0-1 certification (meshes up to `MAX_CERT_DEVICES`)
  By the 0-1 principle a comparison network on m keys sorts every input iff
  it sorts all 2^m 0/1 patterns; and a comparison network that sorts m keys
  sorts m *sorted blocks* when each compare-exchange is replaced by a
  merge-split (Knuth 5.3.4 ex. 38 — the block lemma the engine's docstring
  has always leaned on).  `zero_one_certify` therefore simulates the
  descriptor's device-space substages over all 2^m patterns (vectorised:
  one numpy array of every pattern at once) and checks every result is
  sorted.  For m = 16 that is 65536 patterns x 10 substages — milliseconds,
  and a *proof* for every input on that mesh, not a fuzz run.

`certify_supported_meshes` sweeps every localised policy family over every
power-of-two mesh decomposition up to 16 devices — the repo-wide
certificate the CLI prints and the tests pin.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis.findings import Finding, Report, Severity

#: 0-1 certification is exhaustive (2^m patterns); cap the exhaustive sweep.
MAX_CERT_DEVICES = 16


def _substage_findings(net) -> List[Finding]:
    """Structural violations of one network descriptor (empty = sound)."""
    from repro.core.engine import NetExchange, NetGatherReplay
    out: List[Finding] = []
    m = net.m
    m_inner = net.sizes[-1]
    seen: List[Tuple[int, int]] = []

    def bad(op, msg):
        out.append(Finding("R6", Severity.ERROR, op, message=msg))

    for sub in net.substages():
        tag = f"stage {sub.stage} substage {sub.substage}"
        seen.append((sub.stage, sub.substage))
        p = np.asarray(sub.partner)
        k = np.asarray(sub.keep_low)
        if p.shape != (m,) or k.shape != (m,):
            bad("network", f"{tag}: partner/keep arrays sized {p.shape}/"
                           f"{k.shape}, want ({m},)")
            continue
        if np.any((p < 0) | (p >= m)) or np.any(np.sort(p) != np.arange(m)):
            bad("ppermute", f"{tag}: partner map is not a permutation of "
                            f"the {m} devices: {p.tolist()}")
            continue
        if np.any(p == np.arange(m)):
            bad("ppermute", f"{tag}: device(s) "
                            f"{np.nonzero(p == np.arange(m))[0].tolist()} "
                            f"paired with themselves")
        elif np.any(p[p] != np.arange(m)):
            bad("ppermute", f"{tag}: partner map is not an involution — "
                            f"exchanges are not pairwise")
        if np.any(p != (np.arange(m) ^ sub.stride)):
            bad("ppermute", f"{tag}: partner is not the XOR-2^{{j}} "
                            f"neighbour map at stride {sub.stride}")
        if np.any(k == k[p]):
            d = int(np.nonzero(k == k[p])[0][0])
            bad("merge_split",
                f"{tag}: keep flags not complementary — devices {d} and "
                f"{int(p[d])} both keep the "
                f"{'low' if k[d] else 'high'} half (a chunk is "
                f"{'duplicated' if k[d] else 'dropped'})")

    for lv in net.levels:
        if isinstance(lv, NetExchange):
            na = net.sizes[net.axes.index(lv.axis)]
            src = [s for s, _ in lv.perm]
            dst = [t for _, t in lv.perm]
            if sorted(src) != list(range(na)) or sorted(dst) != list(range(na)):
                bad("ppermute",
                    f"stage {lv.stage} substage {lv.substage}: perm over "
                    f"axis {lv.axis!r} is not a bijection of its {na} "
                    f"indices: {list(lv.perm)}")
            elif any(t != s ^ lv.axis_stride for s, t in lv.perm):
                bad("ppermute",
                    f"stage {lv.stage} substage {lv.substage}: perm does "
                    f"not route the declared stride {lv.axis_stride} on "
                    f"axis {lv.axis!r}")
            if net.hier and lv.stride >= m_inner:
                bad("ppermute",
                    f"stage {lv.stage} substage {lv.substage}: hierarchical "
                    f"plan crosses pods with a pairwise exchange (stride "
                    f"{lv.stride} >= inner size {m_inner}) — cross-pod "
                    f"traffic must go through the per-stage all_gather")
        elif isinstance(lv, NetGatherReplay):
            for rp in lv.replays:
                if rp.stride < m_inner:
                    bad("all_gather",
                        f"stage {rp.stage} substage {rp.substage}: replay "
                        f"at intra-pod stride {rp.stride} — intra-pod "
                        f"exchanges must be pairwise ppermutes")

    want = [(i, j) for i in range(m.bit_length() - 1)
            for j in range(i, -1, -1)]
    if seen != want:
        bad("network", f"(stage, substage) sequence {seen} is not the "
                       f"bitonic schedule {want} — the network cannot sort")
    return out


def zero_one_certify(net) -> Optional[Tuple[int, ...]]:
    """Exhaustively run all 2^m 0/1 patterns; None = sorts, else a witness.

    Simulates the device-space compare-exchange sequence (merge-split at
    chunk granularity == min/max at key granularity, by the block lemma)
    over every pattern at once.  Returns the first unsorted input pattern
    as a witness when certification fails.
    """
    m = net.m
    if m > MAX_CERT_DEVICES:
        raise ValueError(f"0-1 certification is exhaustive; {m} devices "
                         f"exceeds MAX_CERT_DEVICES={MAX_CERT_DEVICES}")
    pats = ((np.arange(1 << m)[:, None] >> np.arange(m)[None, :]) & 1
            ).astype(np.uint8)
    x = pats.copy()
    for sub in net.substages():
        p = np.asarray(sub.partner)
        keep = np.asarray(sub.keep_low)[None, :]
        other = x[:, p]
        x = np.where(keep, np.minimum(x, other), np.maximum(x, other))
    bad = np.nonzero(np.any(np.diff(x.astype(np.int8), axis=1) < 0, axis=1))[0]
    if bad.size == 0:
        return None
    return tuple(int(b) for b in pats[bad[0]])


def r6_network_certification(report: Report, policy, sizes: Sequence[int],
                             axes: Optional[Sequence[str]] = None) -> None:
    """Run R6 over one (policy, mesh-slice): structural + 0-1 certification.

    Non-localised policies have no merge-split network — recorded as a
    note, not a finding (their exchanges are whole-array gathers screened
    by R1/R2).  Meshes beyond `MAX_CERT_DEVICES` get the structural checks
    plus a note that 0-1 ran on the inductive family members instead.
    """
    from repro.core.engine import exchange_network
    try:
        net = exchange_network(policy, sizes, axes)
    except ValueError as e:
        report.notes.append(f"R6 skipped: {e}")
        return
    findings = _substage_findings(net)
    for f in findings:
        report.add(f)
    if net.m > MAX_CERT_DEVICES:
        report.notes.append(
            f"R6: structural checks only on {net.m} devices (0-1 "
            f"certification is exhaustive up to {MAX_CERT_DEVICES})")
        return
    if findings:
        return                      # structure already broken; witness noise
    witness = zero_one_certify(net)
    if witness is None:
        report.notes.append(
            f"R6: 0-1 certified — network sorts all {1 << net.m} patterns "
            f"on mesh {net.sizes} ({policy.name})")
    else:
        report.add(Finding(
            "R6", Severity.ERROR, "network",
            message=f"merge-split network fails the 0-1 principle on mesh "
                    f"{net.sizes}: input pattern {witness} ends unsorted "
                    f"— the engine would silently mis-sort"))


def _mesh_shapes(max_devices: int) -> List[Tuple[Tuple[int, ...], bool]]:
    """Every supported sort-axis shape up to `max_devices`: flat sizes
    (m,) plus every 2-level (pods, inner) power-of-two decomposition."""
    shapes: List[Tuple[Tuple[int, ...], bool]] = []
    m = 2
    while m <= max_devices:
        shapes.append(((m,), False))
        pods = 2
        while pods < m:
            shapes.append(((pods, m // pods), True))
            pods *= 2
        m *= 2
    return shapes


def certify_supported_meshes(max_devices: int = MAX_CERT_DEVICES) -> Dict:
    """The repo-wide certificate: every localised policy x mesh <= cap.

    Returns ``{policy_name: {"certified": [sizes...], "failed":
    [(sizes, witness)...]}}``; an empty ``failed`` everywhere is the
    acceptance contract.  Flat policies certify on every shape (a flat
    plan routes cross-pod strides as pairwise hops); hierarchical policies
    only on multi-axis shapes (their contract requires one).
    """
    from repro.core.engine import exchange_network
    from repro.core.homing import Homing
    from repro.core.localisation import LocalisationPolicy
    policies = {
        "flat": LocalisationPolicy(),
        "hash": LocalisationPolicy(homing=Homing.HASH_INTERLEAVED),
        "hier": LocalisationPolicy.hierarchical(),
        "hier-hash": LocalisationPolicy.hierarchical(inner="hash"),
    }
    out: Dict = {}
    for pname, policy in policies.items():
        cert: List[Tuple[int, ...]] = []
        failed: List = []
        for sizes, multi in _mesh_shapes(max_devices):
            if policy.outer is not None and not multi:
                continue
            net = exchange_network(policy, sizes)
            if _substage_findings(net):
                failed.append((sizes, "structural"))
                continue
            witness = zero_one_certify(net)
            if witness is None:
                cert.append(sizes)
            else:
                failed.append((sizes, witness))
        out[policy.name] = {"policy": pname, "certified": cert,
                            "failed": failed}
    return out
