"""Committed known-bad fixtures the analyzer must provably flag.

Each fixture is the minimal embodiment of one silent-corruption class the
new rules exist to exclude; the tests (and anyone auditing the analyzer)
can run R5-R8 against them and watch the exact finding fire.  They are
library code, not test-local lambdas, so the CLI and future rules can
reuse them as regression anchors.

  `overlapping_index_map`  — R5 ERROR: two grid steps write the same
                             output block (i // 2 collapses pairs).
  `gapped_index_map`       — R5 WARN: half the output rows never written.
  `oob_index_map`          — R5 ERROR: input blocks read past the array.
  `dead_lane_kernel`       — R8 WARN: a `pl.when` lane no grid index
                             satisfies.
  `nonbijective_network`   — R6 ERROR (structural): one substage's
                             ppermute sends every source to device 0.
  `inverted_keep_network`  — R6 ERROR (0-1): keep flags swapped on a
                             whole substage — still pairwise-complementary
                             (structurally clean), but the network no
                             longer sorts; only the 0-1 sweep catches it.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _copy_kernel(x_ref, y_ref):
    y_ref[...] = x_ref[...]


def overlapping_index_map(rows: int = 4, cols: int = 128):
    """pallas_call whose output index_map writes each block twice."""
    def call(x):
        return pl.pallas_call(
            _copy_kernel, grid=(rows,),
            in_specs=[pl.BlockSpec((1, cols), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((1, cols), lambda i: (i // 2, 0)),
            out_shape=jax.ShapeDtypeStruct((rows, cols), x.dtype),
            interpret=True)(x)
    return jax.make_jaxpr(call)(
        jax.ShapeDtypeStruct((rows, cols), jnp.float32))


def gapped_index_map(rows: int = 4, cols: int = 128):
    """pallas_call whose grid covers only the first half of the output."""
    def call(x):
        return pl.pallas_call(
            _copy_kernel, grid=(rows // 2,),
            in_specs=[pl.BlockSpec((1, cols), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((1, cols), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((rows, cols), x.dtype),
            interpret=True)(x)
    return jax.make_jaxpr(call)(
        jax.ShapeDtypeStruct((rows, cols), jnp.float32))


def oob_index_map(rows: int = 4, cols: int = 128):
    """pallas_call reading input blocks past the end of the array."""
    def call(x):
        return pl.pallas_call(
            _copy_kernel, grid=(rows,),
            in_specs=[pl.BlockSpec((1, cols), lambda i: (i + rows // 2, 0))],
            out_specs=pl.BlockSpec((1, cols), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((rows, cols), x.dtype),
            interpret=True)(x)
    return jax.make_jaxpr(call)(
        jax.ShapeDtypeStruct((rows, cols), jnp.float32))


def dead_lane_kernel(rows: int = 4, cols: int = 128):
    """pallas_call with a pl.when lane no program_id ever satisfies."""
    def kernel(x_ref, y_ref):
        y_ref[...] = x_ref[...]

        @pl.when(pl.program_id(0) == rows + 3)
        def _():
            y_ref[...] = y_ref[...] * 2.0

    def call(x):
        return pl.pallas_call(
            kernel, grid=(rows,),
            in_specs=[pl.BlockSpec((1, cols), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((1, cols), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((rows, cols), x.dtype),
            interpret=True)(x)
    return jax.make_jaxpr(call)(
        jax.ShapeDtypeStruct((rows, cols), jnp.float32))


def nonbijective_network(m: int = 4):
    """A flat exchange network whose first perm routes everyone to 0."""
    from repro.core.engine import exchange_network
    from repro.core.localisation import LocalisationPolicy
    net = exchange_network(LocalisationPolicy(), (m,))
    lv0 = net.levels[0]
    bad = dataclasses.replace(lv0, perm=tuple((s, 0) for s, _ in lv0.perm))
    return dataclasses.replace(net, levels=(bad,) + net.levels[1:])


def inverted_keep_network(m: int = 4):
    """A structurally-sound network that fails the 0-1 principle: the
    final stage's deepest substage keeps the wrong halves (flags still
    complementary across each pair, so only 0-1 certification can tell)."""
    from repro.core.engine import exchange_network
    from repro.core.localisation import LocalisationPolicy
    net = exchange_network(LocalisationPolicy(), (m,))
    last = m.bit_length() - 2           # final merge stage index
    levels = tuple(
        dataclasses.replace(lv, keep_low=tuple(not b for b in lv.keep_low))
        if (lv.stage, lv.substage) == (last, 0) else lv
        for lv in net.levels)
    return dataclasses.replace(net, levels=levels)
