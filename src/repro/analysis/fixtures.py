"""Committed known-bad fixtures the analyzer must provably flag.

Each fixture is the minimal embodiment of one silent-corruption class the
new rules exist to exclude; the tests (and anyone auditing the analyzer)
can run R5-R8 against them and watch the exact finding fire.  They are
library code, not test-local lambdas, so the CLI and future rules can
reuse them as regression anchors.

  `overlapping_index_map`  — R5 ERROR: two grid steps write the same
                             output block (i // 2 collapses pairs).
  `gapped_index_map`       — R5 WARN: half the output rows never written.
  `oob_index_map`          — R5 ERROR: input blocks read past the array.
  `dead_lane_kernel`       — R8 WARN: a `pl.when` lane no grid index
                             satisfies.
  `nonbijective_network`   — R6 ERROR (structural): one substage's
                             ppermute sends every source to device 0.
  `inverted_keep_network`  — R6 ERROR (0-1): keep flags swapped on a
                             whole substage — still pairwise-complementary
                             (structurally clean), but the network no
                             longer sorts; only the 0-1 sweep catches it.
  `mutant_scheduler`       — R9 ERROR: the production transitions with one
                             named mutation switched on via the
                             `SchedConfig.mutations` hook; each breaks
                             exactly one certified invariant:
                               "no_aging"     skip aging off  → I2
                               "drop_charge"  charge dropped  → I1
                               "greedy_spill" donor order ignored → I7
                               "leak_page"    page release dropped → I8
  `hbm_hog_module`         — R10 ERROR (vs. a 32 MiB test ceiling): two
                             16 MiB temporaries and the 16 MiB result all
                             live at the ROOT — 64 MiB peak.
  `branch_mismatch_module` — R11 ERROR: a `conditional` with an all-reduce
                             in one branch only; devices disagreeing on
                             the predicate deadlock under multi-process.
  `data_dependent_loop_module`
                           — R11 WARN: a `while` with no compiler-proven
                             trip count whose body issues an all-reduce.
  `consistent_branches_module`
                           — R11 clean anchor: both branches carry the
                             identical all-reduce, so the collective is
                             control-independent and must NOT be flagged.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _copy_kernel(x_ref, y_ref):
    y_ref[...] = x_ref[...]


def overlapping_index_map(rows: int = 4, cols: int = 128):
    """pallas_call whose output index_map writes each block twice."""
    def call(x):
        return pl.pallas_call(
            _copy_kernel, grid=(rows,),
            in_specs=[pl.BlockSpec((1, cols), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((1, cols), lambda i: (i // 2, 0)),
            out_shape=jax.ShapeDtypeStruct((rows, cols), x.dtype),
            interpret=True)(x)
    return jax.make_jaxpr(call)(
        jax.ShapeDtypeStruct((rows, cols), jnp.float32))


def gapped_index_map(rows: int = 4, cols: int = 128):
    """pallas_call whose grid covers only the first half of the output."""
    def call(x):
        return pl.pallas_call(
            _copy_kernel, grid=(rows // 2,),
            in_specs=[pl.BlockSpec((1, cols), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((1, cols), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((rows, cols), x.dtype),
            interpret=True)(x)
    return jax.make_jaxpr(call)(
        jax.ShapeDtypeStruct((rows, cols), jnp.float32))


def oob_index_map(rows: int = 4, cols: int = 128):
    """pallas_call reading input blocks past the end of the array."""
    def call(x):
        return pl.pallas_call(
            _copy_kernel, grid=(rows,),
            in_specs=[pl.BlockSpec((1, cols), lambda i: (i + rows // 2, 0))],
            out_specs=pl.BlockSpec((1, cols), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((rows, cols), x.dtype),
            interpret=True)(x)
    return jax.make_jaxpr(call)(
        jax.ShapeDtypeStruct((rows, cols), jnp.float32))


def dead_lane_kernel(rows: int = 4, cols: int = 128):
    """pallas_call with a pl.when lane no program_id ever satisfies."""
    def kernel(x_ref, y_ref):
        y_ref[...] = x_ref[...]

        @pl.when(pl.program_id(0) == rows + 3)
        def _():
            y_ref[...] = y_ref[...] * 2.0

    def call(x):
        return pl.pallas_call(
            kernel, grid=(rows,),
            in_specs=[pl.BlockSpec((1, cols), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((1, cols), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((rows, cols), x.dtype),
            interpret=True)(x)
    return jax.make_jaxpr(call)(
        jax.ShapeDtypeStruct((rows, cols), jnp.float32))


def nonbijective_network(m: int = 4):
    """A flat exchange network whose first perm routes everyone to 0."""
    from repro.core.engine import exchange_network
    from repro.core.localisation import LocalisationPolicy
    net = exchange_network(LocalisationPolicy(), (m,))
    lv0 = net.levels[0]
    bad = dataclasses.replace(lv0, perm=tuple((s, 0) for s, _ in lv0.perm))
    return dataclasses.replace(net, levels=(bad,) + net.levels[1:])


#: the invariant each scheduler mutation provably violates (the R9 tests
#: assert the witness carries exactly this tag)
MUTANT_INVARIANT = {
    "no_aging": "I2-starvation",
    "drop_charge": "I1-uncharged-move",
    "greedy_spill": "I7-spill-order",
    "leak_page": "I8-page-leak",
}

#: smallest DEFAULT_LATTICE entry on which each mutation is caught — the
#: witness search stops at the first violation, so these certify fast
_MUTANT_ENTRY = {
    "no_aging": "homed-1x2",
    "drop_charge": "homed-2x1",
    "greedy_spill": "homed-2x1",
    "leak_page": "homed-paged",
}


def mutant_scheduler(mutation: str):
    """A `LatticeEntry` running the production scheduler transitions with
    one named mutation enabled — `schedcheck.certify` must return a
    minimal witness tagged `MUTANT_INVARIANT[mutation]` for it."""
    from repro.analysis.schedcheck import DEFAULT_LATTICE
    if mutation not in MUTANT_INVARIANT:
        raise ValueError(f"unknown scheduler mutation {mutation!r}; "
                         f"known: {', '.join(MUTANT_INVARIANT)}")
    entry = next(e for e in DEFAULT_LATTICE
                 if e.name == _MUTANT_ENTRY[mutation])
    return dataclasses.replace(
        entry, name=f"{entry.name}+{mutation}",
        cfg=dataclasses.replace(entry.cfg,
                                mutations=frozenset({mutation})))


def hbm_hog_module() -> str:
    """HLO whose entry holds 64 MiB live at the ROOT (R10 vs 32 MiB)."""
    return """\
HloModule r10_hbm_hog

ENTRY %main (x: f32[4194304]) -> f32[4194304] {
  %x = f32[4194304]{0} parameter(0)
  %a = f32[4194304]{0} negate(%x)
  %b = f32[4194304]{0} exponential(%x)
  ROOT %r = f32[4194304]{0} add(%a, %b)
}
"""


def branch_mismatch_module() -> str:
    """HLO with a conditional whose branches disagree on collectives."""
    return """\
HloModule r11_branch_mismatch

%with_ar (p0: f32[8]) -> f32[8] {
  %p0 = f32[8]{0} parameter(0)
  ROOT %ar = f32[8]{0} all-reduce(%p0), replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%add
}

%without_ar (p1: f32[8]) -> f32[8] {
  %p1 = f32[8]{0} parameter(0)
  ROOT %neg = f32[8]{0} negate(%p1)
}

ENTRY %main (x: f32[8], p: pred[]) -> f32[8] {
  %x = f32[8]{0} parameter(0)
  %p = pred[] parameter(1)
  ROOT %cond = f32[8]{0} conditional(%p, %x, %x), true_computation=%with_ar, false_computation=%without_ar
}
"""


def data_dependent_loop_module() -> str:
    """HLO with a trip-count-unknown while whose body all-reduces."""
    return """\
HloModule r11_data_dependent_loop

%loop_cond (pc: (s32[], f32[8])) -> pred[] {
  %pc = (s32[], f32[8]{0}) parameter(0)
  %i = s32[] get-tuple-element(%pc), index=0
  %j = s32[] get-tuple-element(%pc), index=0
  ROOT %lt = pred[] compare(%i, %j), direction=LT
}

%loop_body (pb: (s32[], f32[8])) -> (s32[], f32[8]) {
  %pb = (s32[], f32[8]{0}) parameter(0)
  %i = s32[] get-tuple-element(%pb), index=0
  %v = f32[8]{0} get-tuple-element(%pb), index=1
  %ar = f32[8]{0} all-reduce(%v), replica_groups={{0,1}}, to_apply=%add
  ROOT %t = (s32[], f32[8]{0}) tuple(%i, %ar)
}

ENTRY %main (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p = (s32[], f32[8]{0}) parameter(0)
  ROOT %w = (s32[], f32[8]{0}) while(%p), condition=%loop_cond, body=%loop_body
}
"""


def consistent_branches_module() -> str:
    """HLO with a conditional whose branches issue the same all-reduce —
    control-independent, must stay clean under R11."""
    return """\
HloModule r11_consistent_branches

%br_a (pa: f32[8]) -> f32[8] {
  %pa = f32[8]{0} parameter(0)
  ROOT %ar = f32[8]{0} all-reduce(%pa), replica_groups={{0,1}}, to_apply=%add
}

%br_b (pb: f32[8]) -> f32[8] {
  %pb = f32[8]{0} parameter(0)
  %neg = f32[8]{0} negate(%pb)
  ROOT %ar2 = f32[8]{0} all-reduce(%neg), replica_groups={{0,1}}, to_apply=%add
}

ENTRY %main (x: f32[8], p: pred[]) -> f32[8] {
  %x = f32[8]{0} parameter(0)
  %p = pred[] parameter(1)
  ROOT %cond = f32[8]{0} conditional(%p, %x, %x), true_computation=%br_a, false_computation=%br_b
}
"""


def inverted_keep_network(m: int = 4):
    """A structurally-sound network that fails the 0-1 principle: the
    final stage's deepest substage keeps the wrong halves (flags still
    complementary across each pair, so only 0-1 certification can tell)."""
    from repro.core.engine import exchange_network
    from repro.core.localisation import LocalisationPolicy
    net = exchange_network(LocalisationPolicy(), (m,))
    last = m.bit_length() - 2           # final merge stage index
    levels = tuple(
        dataclasses.replace(lv, keep_low=tuple(not b for b in lv.keep_low))
        if (lv.stage, lv.substage) == (last, 0) else lv
        for lv in net.levels)
    return dataclasses.replace(net, levels=levels)
