"""Rule R9: exhaustively certify the home-aware scheduler's invariants.

The serving scheduler is the runtime's *placement authority*: every decode
request lands where it says, every cache byte moves when it says.  PR 7's
`zero_one_certify` proved the exchange network by running the descriptor
the runtime executes over its entire input space; this module does the
same for the scheduler — `runtime.scheduler` now exposes routing, wave
formation, spill and eviction as pure transition functions
(`route_t`/`form_wave_t`/`complete_t`: state in, ``(state', placements,
charges)`` out), so the checker explores **all interleavings of arrivals
and wave boundaries** over a small-config lattice by breadth-first search
on canonicalized states, checking at every wave transition:

I1 off-home-unless-charged
    a placement landing off its session's bound home carries a `Charge`
    (or reuses a cache copy already charged this wave) — no silent moves,
    the "invisible coherence traffic" failure mode.
I2 starvation bound
    no queued entry is ever skipped more than ``max_skip`` waves — the
    aging floor provably forces an aged entry's span into the target.
I3 work conservation
    a formed wave never leaves a free slot while an admissible entry
    (span <= target) waits in any queue the config can see.
I4 eviction-never-migrates + capacity
    a binding leaves the table only by eviction on its own home; no home
    ever holds more than ``session_capacity`` bindings.
I5 no double-booking / binding leak
    slots and requests are placed at most once, placements come from the
    queues, fills are front-first, and in-flight fork marks are consumed
    by the wave that made them.
I6 charges equal the replayed moves
    an *independent* accounting model replays the placements in decision
    order against the pre-wave binding table; the transition's charges —
    bytes, inter/intra-pod split, fork-vs-migrate — must match move for
    move, and the post-state bindings must equal the model's.
I7 spill donor minimality
    every spilled placement picked the donor the cost order
    ``(relayout cost, crosses pod, -queue depth, donor, index)`` ranks
    first — spills pay the cheapest relayout the queues offered.
I8 page refcounts never leak
    on paged configs (``page_capacity > 0``) an independent model replays
    every placement's page acquire and every completion's release against
    the pre-state pools: the transition's pools must match page for page,
    a placement's attached-prefix count must equal what the *pre-wave*
    key set offers on its own home (attach never crosses homes and never
    sees a wave-mate's in-flight insert), no home ever pools more than
    ``page_capacity`` pages, and a quiescent pool — nothing in flight —
    holds only refs==0 pages.

Paged entries give each sessioned arrival a block chain keyed on its
session (two requests of one session share prompt pages, the radix-hit
case); ``continuous=True`` entries drop the atomic form+complete wave for
the continuous-batching event alphabet the real server loop executes —
``form`` over the currently-free slot subset and per-slot ``finish`` —
so mid-wave refill and page pinning across overlapping lifetimes are
explored exhaustively too.

States are canonicalized (request ids relabelled in queue order, sessions
by first appearance, ``last_used`` timestamps by dense LRU rank, pool
pages by relabelled key and refcount) so the search closes over a finite
lattice; BFS order makes the first violation a *minimal witness* — the
shortest arrival/wave script reaching it, which `Witness.format()` prints
as a replayable trace.  The committed mutants in `analysis.fixtures`
(aging off, charge dropped, greedy spill, page release dropped) each
produce such a witness; the production config produces none, and the CLI
prints the certificate (`certify_lattice`) next to R6's.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import (Dict, FrozenSet, List, NamedTuple, Optional, Sequence,
                    Tuple)

from repro.analysis.findings import Finding, Report, Severity
from repro.runtime import kvpool
from repro.runtime.scheduler import (Charge, ReqInfo, SchedConfig,
                                     SchedState, Served, complete_t,
                                     form_wave_t, initial_state, route_t)

#: exploration is exhaustive; refuse lattices whose closure outgrows this
MAX_STATES = 200_000


@dataclass(frozen=True)
class LatticeEntry:
    """One certified configuration plus the arrival space explored on it.

    ``blocks_per_req`` gives every *sessioned* arrival a prompt block
    chain ``((session, 0), (session, 1), ...)`` — session identity stands
    in for prompt content, so a returning session is the radix-hit case.
    ``continuous=True`` swaps the atomic wave event for the continuous-
    batching alphabet: ``form`` over the free slot subset, per-slot
    ``finish``."""
    name: str
    cfg: SchedConfig
    max_arrivals: int = 5
    spans: Tuple[int, ...] = (1, 2)
    max_sessions: int = 2
    blocks_per_req: int = 0
    continuous: bool = False


class _Running(NamedTuple):
    """One in-flight slot of a continuous-mode exploration node: what a
    later ``finish`` event needs to complete it."""
    slot: int
    rid: object
    session: object
    span: int
    home: int
    blocks: Tuple
    attached: int


@dataclass(frozen=True)
class Witness:
    """A minimal violating run: the event script and what broke."""
    config: str
    invariant: str
    events: Tuple[str, ...]
    violation: str

    def format(self) -> str:
        script = " -> ".join(self.events) if self.events else "(initial)"
        return (f"{self.config}: {self.invariant} after [{script}]: "
                f"{self.violation}")


class _Violation(Exception):
    def __init__(self, invariant: str, message: str):
        super().__init__(message)
        self.invariant = invariant


# ---------------------------------------------------------------------------
# canonicalization: close the search over relabelled-isomorphic states
# ---------------------------------------------------------------------------
def _canonical_key(state: SchedState, arrivals_left: int,
                   running: Tuple[_Running, ...] = ()) -> Tuple:
    sess_map: Dict[object, int] = {}

    def sess(s):
        if s is None:
            return None
        if s not in sess_map:
            sess_map[s] = len(sess_map)
        return sess_map[s]

    # bindings first: their order is LRU-tie-breaking insertion order;
    # pool pages share the dense-rank timeline (acquire/release touch)
    stamps = {b.last_used for b in state.bindings}
    stamps |= {p.last_used for _, pgs in state.pools for p in pgs}
    ranks = {t: i for i, t in enumerate(sorted(stamps))}
    binds = tuple((sess(b.session), b.home, b.tokens, ranks[b.last_used])
                  for b in state.bindings)
    fifo = tuple((e.span, sess(e.session)) for e in state.fifo)
    queues = tuple((h, tuple((e.req.span, sess(e.req.session), e.skips)
                             for e in q))
                   for h, q in state.queues)
    # lattice block keys are (session, index) pairs — relabel the session
    # half so pools of isomorphic histories collapse
    pools = tuple((h, tuple((sess(p.key[0]), p.key[1], p.refs,
                             ranks[p.last_used]) for p in pgs))
                  for h, pgs in state.pools)
    run = tuple((r.slot, sess(r.session), r.span, r.home, r.attached,
                 r.rid in state.forked)
                for r in sorted(running, key=lambda r: r.slot))
    return (binds, fifo, queues, bool(state.forked), pools, run,
            arrivals_left)


# ---------------------------------------------------------------------------
# the independent accounting model (invariants I1, I6, I7, parts of I5)
# ---------------------------------------------------------------------------
def _audit_wave(cfg: SchedConfig, pre: SchedState, post: SchedState,
                placements, charges,
                free_slots: Optional[Sequence[int]] = None) -> None:
    """Replay the wave's placements in decision order against the pre-wave
    tables and demand the transition's charges and post-state match.
    ``free_slots`` is the continuous-refill slot subset (None = the whole
    server, the atomic wave boundary)."""
    slots_of = cfg.slots_of
    fs = set(range(cfg.n_slots)) if free_slots is None else set(free_slots)
    # I5: slots/requests at most once, slot owned by the placement's home
    slots = [p.slot for p in placements]
    if len(set(slots)) != len(slots):
        raise _Violation("I5-double-booking",
                         f"slot placed twice: {sorted(slots)}")
    rids = [p.rid for p in placements]
    if len(set(rids)) != len(rids):
        raise _Violation("I5-double-booking",
                         f"request placed twice: {rids}")
    for p in placements:
        if cfg.owners[p.slot] != p.home:
            raise _Violation("I5-double-booking",
                             f"slot {p.slot} owned by "
                             f"{cfg.owners[p.slot]}, placed for {p.home}")
        if p.slot not in fs:
            raise _Violation("I5-double-booking",
                             f"slot {p.slot} refilled while occupied")

    if cfg.policy == "fifo":
        want = [e.rid for e in pre.fifo[:len(fs)]]
        if rids != want:
            raise _Violation("I5-double-booking",
                             f"fifo wave {rids} is not the queue prefix "
                             f"{want}")
    else:
        # fill placements on each home must be the front-first admissible
        # prefix of that home's own pre-wave queue (spills exempt)
        for h in cfg.homes:
            fills = [p.rid for p in placements
                     if p.home == h and p.spilled_from is None]
            q = [e.req for e in pre.queue(h)[:cfg.lookahead]]
            admissible = [r.rid for r in q if r.span <= charges.target]
            if fills != admissible[:len(fills)]:
                raise _Violation(
                    "I5-double-booking",
                    f"home {h} fill {fills} is not the front-first "
                    f"admissible prefix {admissible[:len(fills)]}")

    # replay: model queues (entries removed as placed), bindings, sites
    queues = {h: [e.req for e in q] for h, q in pre.queues}
    bindings = {b.session: b for b in pre.bindings}
    sites: Dict[object, set] = {}
    forked = set(pre.forked)
    moves: List[Charge] = []
    info = {e.req.rid: e.req for _, q in pre.queues for e in q}
    info.update({e.rid: e for e in pre.fifo})
    for p in placements:
        req = info.get(p.rid)
        if req is None:
            raise _Violation("I5-binding-leak",
                             f"placement of rid {p.rid} not found in any "
                             f"pre-wave queue")
        if cfg.policy == "homed":
            src_q = queues[p.home if p.spilled_from is None
                           else p.spilled_from]
            src_q.remove(req)
        b = bindings.get(req.session) if req.session is not None else None
        if b is None:
            continue
        # fork iff the session still has work queued at its bound home
        migrate = not (b.home != p.home and b.home in queues
                       and any(r.session == req.session
                               for r in queues[b.home]))
        ss = sites.setdefault(req.session, {b.home})
        if p.home not in ss and p.home != b.home:
            # off-home landing without a cache copy charged onto this
            # home earlier in the wave: a Charge is owed (I1), and the
            # move-for-move comparison below enforces it
            moves.append(Charge(
                rid=p.rid, session=req.session, src=b.home, dst=p.home,
                tokens=b.tokens, nbytes=b.tokens * cfg.bytes_per_token,
                inter_pod=cfg.pod(b.home) != cfg.pod(p.home),
                migrate=migrate))
        ss.add(p.home)
        if migrate:
            bindings[req.session] = b._replace(home=p.home)
        elif p.home != b.home:
            forked.add(p.rid)

    if tuple(moves) != charges.moves:
        # distinguish the silent-move class from a mere accounting skew
        charged = {(c.session, c.dst) for c in charges.moves}
        missing = [c for c in moves if (c.session, c.dst) not in charged]
        inv = "I1-uncharged-move" if missing else "I6-charge-mismatch"
        raise _Violation(
            inv, f"transition charged {list(charges.moves)}, independent "
                 f"replay expects {moves}")
    if {b.session: b.home for b in post.bindings} != \
            {s: b.home for s, b in bindings.items()}:
        raise _Violation(
            "I6-charge-mismatch",
            f"post-wave binding homes "
            f"{{ {', '.join(f'{b.session}:{b.home}' for b in post.bindings)} }}"
            f" diverge from the replayed fork/migrate model")
    if post.forked != frozenset(forked):
        raise _Violation("I5-binding-leak",
                         f"fork marks {set(post.forked)} != replayed "
                         f"{forked}")

    # I7: every spill picked the minimal-cost donor available at its turn
    if cfg.policy == "homed":
        _audit_spills(cfg, pre, placements, charges)

    # I3: free slots + admissible leftover work = a broken conservation law
    placed_per_home = {h: sum(1 for p in placements if p.home == h)
                       for h in cfg.homes}
    cap_of = {h: sum(1 for s in ss if s in fs) for h, ss in slots_of.items()}
    if cfg.policy == "homed" and charges.target:
        for h in cfg.homes:
            if placed_per_home[h] >= cap_of[h]:
                continue
            leftovers = [e.req for _, q in post.queues for e in
                         q[:cfg.lookahead]]
            stuck = [r.rid for r in leftovers if r.span <= charges.target]
            if stuck:
                raise _Violation(
                    "I3-work-conservation",
                    f"home {h} left {cap_of[h] - placed_per_home[h]} "
                    f"slot(s) free while rid(s) {stuck} (span <= target "
                    f"{charges.target}) stayed queued")


def _audit_spills(cfg: SchedConfig, pre: SchedState, placements,
                  charges) -> None:
    """Re-run the donor scan for each spilled placement and demand the
    recorded pick is cost-minimal at that point of the replay."""
    queues = {h: [e.req for e in q] for h, q in pre.queues}
    bindings = {b.session: b for b in pre.bindings}
    sites: Dict[object, set] = {}

    def touch(req, home):
        b = bindings.get(req.session) if req.session is not None else None
        if b is None:
            return
        ss = sites.setdefault(req.session, {b.home})
        migrate = not (b.home != home and b.home in queues
                       and any(r.session == req.session
                               for r in queues[b.home]))
        ss.add(home)
        if migrate:
            bindings[req.session] = b._replace(home=home)

    for p in placements:
        donor = p.home if p.spilled_from is None else p.spilled_from
        req = next(r for r in queues[donor] if r.rid == p.rid)
        if p.spilled_from is not None:
            h = p.home
            best = None
            for d in cfg.homes:
                if d == h:
                    continue
                for i, r in enumerate(queues[d][:cfg.lookahead]):
                    if r.span > charges.target:
                        continue
                    b = (bindings.get(r.session)
                         if r.session is not None else None)
                    cost = (0 if b is None or b.home == h
                            or h in sites.get(r.session, ())
                            else b.tokens)
                    key = (cost, cfg.pod(d) != cfg.pod(h),
                           -len(queues[d]), d, i)
                    if best is None or key < best[0]:
                        best = (key, d, r)
            if best is not None and (best[1], best[2].rid) != (donor,
                                                               p.rid):
                raise _Violation(
                    "I7-spill-order",
                    f"spill onto home {h} took rid {p.rid} from donor "
                    f"{donor}, but rid {best[2].rid} from donor {best[1]} "
                    f"was cheaper (key {best[0]})")
        queues[donor].remove(req)
        touch(req, p.home)


# ---------------------------------------------------------------------------
# the independent page-accounting model (invariant I8)
# ---------------------------------------------------------------------------
def _cmp_pools(state_pools, model: Dict, stage: str) -> None:
    got = {h: tuple((p.key, p.refs) for p in pgs) for h, pgs in state_pools}
    want = {h: tuple((p.key, p.refs) for p in pgs)
            for h, pgs in model.items()}
    if got != want:
        raise _Violation(
            "I8-page-leak",
            f"pool refcounts after {stage} diverge from the independent "
            f"acquire/release replay: transition holds {got}, replay "
            f"expects {want}")


def _audit_pages(cfg: SchedConfig, pre: SchedState, formed: SchedState,
                 post: SchedState, placements, served, now: float,
                 quiescent: bool = False) -> None:
    """Invariant I8: replay every placement's page acquire (in decision
    order, against the *pre*-state pools and their frozen key snapshot)
    and every completion's release, and demand the transition's pools
    match page for page.  Also proves the attach count is exactly the
    pre-wave longest-prefix hit on the placement's own home (attach never
    crosses homes, never sees a wave-mate's insert), the capacity bound,
    and — when nothing is left in flight — that every pooled page is back
    to refs==0."""
    if cfg.page_capacity <= 0:
        return
    info = {e.req.rid: e.req for _, q in pre.queues for e in q}
    info.update({e.rid: e for e in pre.fifo})
    pools = {h: p for h, p in pre.pools}
    known = {h: frozenset(pg.key for pg in p) for h, p in pre.pools}
    for p in placements:
        req = info.get(p.rid)
        blocks = req.blocks if req is not None else ()
        pages, hit = kvpool.acquire(pools.get(p.home, ()), blocks,
                                    cfg.page_capacity, now,
                                    known.get(p.home, frozenset()))
        pools[p.home] = pages
        if hit != p.attached:
            raise _Violation(
                "I8-attach",
                f"placement of rid {p.rid} on home {p.home} reports "
                f"{p.attached} attached page(s); the pre-wave pool "
                f"offers {hit}")
    _cmp_pools(formed.pools, pools, "formation")
    for h, pgs in pools.items():
        if len(pgs) > cfg.page_capacity:
            raise _Violation(
                "I8-capacity",
                f"home {h} pools {len(pgs)} pages "
                f"(page_capacity {cfg.page_capacity})")
    for sv in served:
        pools[sv.home] = kvpool.release(pools.get(sv.home, ()), sv.blocks,
                                        now)
    _cmp_pools(post.pools, pools, "completion")
    if quiescent:
        for h, pgs in post.pools:
            for pg in pgs:
                if pg.refs:
                    raise _Violation(
                        "I8-page-leak",
                        f"page {pg.key} on home {h} holds {pg.refs} "
                        f"ref(s) with nothing in flight — a release "
                        f"was dropped")


# ---------------------------------------------------------------------------
# the exhaustive exploration
# ---------------------------------------------------------------------------
def certify(entry: LatticeEntry) -> Tuple[Optional[Witness], int]:
    """Explore every arrival/wave interleaving of one lattice entry.

    Returns ``(witness, states_explored)`` — witness None means every
    reachable transition satisfied I1–I8 (a proof over this config's
    event space, not a sample).  BFS guarantees the witness is minimal.
    """
    cfg = entry.cfg
    init = initial_state(cfg)
    start = _canonical_key(init, entry.max_arrivals)
    seen = {start}
    frontier = deque([(init, entry.max_arrivals, (), ())])
    explored = 0
    try:
        while frontier:
            state, left, running, path = frontier.popleft()
            explored += 1
            if explored > MAX_STATES:
                raise RuntimeError(
                    f"{entry.name}: lattice closure exceeds MAX_STATES="
                    f"{MAX_STATES}; shrink the entry — a capped sweep is "
                    f"not a certificate")
            for ev, nxt, nleft, nrun in _successors(cfg, entry, state,
                                                    left, running, path):
                key = _canonical_key(nxt, nleft, nrun)
                if key in seen:
                    continue
                seen.add(key)
                frontier.append((nxt, nleft, nrun, path + (ev,)))
    except _WitnessFound as wf:
        return wf.witness, explored
    return None, explored


def _now(state: SchedState) -> float:
    """A clock strictly past every stamp the state carries (bindings and
    pool pages share the LRU timeline)."""
    stamps = [b.last_used for b in state.bindings]
    stamps += [p.last_used for _, pgs in state.pools for p in pgs]
    return max(stamps, default=0.0) + 1.0


def _successors(cfg: SchedConfig, entry: LatticeEntry, state: SchedState,
                left: int, running: Tuple[_Running, ...], path):
    """Yield ``(event, state', arrivals_left', running')`` or raise via
    audit.

    Arrival events draw from the entry's span alphabet crossed with the
    visible session choices (each existing session, one fresh name while
    under ``max_sessions``, and the session-less request); the wave event
    is the atomic form+serve+complete boundary the legacy server loop
    executes — or, on ``continuous`` entries, the split ``form`` (over
    the free slot subset) and per-slot ``finish`` events of the
    continuous-batching loop.
    """
    if left > 0:
        sessions = sorted({b.session for b in state.bindings}
                          | {e.session for e in state.fifo
                             if e.session is not None}
                          | {e.req.session for _, q in state.queues
                             for e in q if e.req.session is not None})
        choices: List[object] = [None] + sessions
        if len(sessions) < entry.max_sessions:
            fresh = 0
            while f"s{fresh}" in sessions:
                fresh += 1
            choices.append(f"s{fresh}")
        rid = f"a{entry.max_arrivals - left}"
        for span in entry.spans:
            for sess in choices:
                blocks = (tuple((sess, i)
                                for i in range(entry.blocks_per_req))
                          if sess is not None else ())
                nxt, _home = route_t(
                    cfg, state, ReqInfo(rid=rid, span=span, session=sess,
                                        blocks=blocks))
                yield (f"arrive({rid},span={span},sess={sess})", nxt,
                       left - 1, running)
    if entry.continuous:
        yield from _continuous_events(cfg, entry, state, left, running,
                                      path)
        return
    if state.pending:
        now = _now(state)
        mid, placements, charges = form_wave_t(cfg, state, now=now)
        served = [Served(rid=p.rid, session=_session_of(state, p.rid),
                         home=p.home, tokens=_span_of(state, p.rid),
                         blocks=_blocks_of(state, p.rid))
                  for p in placements]
        post, evicted = complete_t(cfg, mid, served, now)
        try:
            _audit_wave(cfg, state, mid, placements, charges)
            _check_post(cfg, state, post, served, evicted)
            _audit_pages(cfg, state, mid, post, placements, served, now,
                         quiescent=True)
        except _Violation as v:
            raise _WitnessFound(Witness(
                config=entry.name, invariant=v.invariant,
                events=path + ("wave",), violation=str(v))) from None
        yield ("wave", post, left, running)


def _continuous_events(cfg: SchedConfig, entry: LatticeEntry,
                       state: SchedState, left: int,
                       running: Tuple[_Running, ...], path):
    """The continuous-batching event alphabet: a ``form`` refills only
    the free slot subset while occupied neighbours keep decoding; a
    ``finish`` completes one in-flight slot (any interleaving — decode
    lengths are adversarial)."""
    occupied = {r.slot for r in running}
    free = [s for s in range(cfg.n_slots) if s not in occupied]
    if state.pending and free:
        now = _now(state)
        mid, placements, charges = form_wave_t(cfg, state, free=free,
                                               now=now)
        if placements:
            try:
                _audit_wave(cfg, state, mid, placements, charges,
                            free_slots=free)
                _audit_pages(cfg, state, mid, mid, placements, (), now)
            except _Violation as v:
                raise _WitnessFound(Witness(
                    config=entry.name, invariant=v.invariant,
                    events=path + ("form",), violation=str(v))) from None
            nrun = running + tuple(
                _Running(p.slot, p.rid, _session_of(state, p.rid),
                         _span_of(state, p.rid), p.home,
                         _blocks_of(state, p.rid), p.attached)
                for p in placements)
            yield ("form", mid, left, nrun)
    for r in running:
        now = _now(state)
        served = [Served(rid=r.rid, session=r.session, home=r.home,
                         tokens=r.span, blocks=r.blocks)]
        post, evicted = complete_t(cfg, state, served, now)
        nrun = tuple(x for x in running if x.slot != r.slot)
        try:
            _check_post(cfg, state, post, served, evicted,
                        inflight=frozenset(x.rid for x in nrun))
            _audit_pages(cfg, state, state, post, (), served, now,
                         quiescent=not nrun)
        except _Violation as v:
            raise _WitnessFound(Witness(
                config=entry.name, invariant=v.invariant,
                events=path + (f"finish({r.slot})",),
                violation=str(v))) from None
        yield (f"finish({r.slot})", post, left, nrun)


class _WitnessFound(Exception):
    def __init__(self, witness: Witness):
        super().__init__(witness.format())
        self.witness = witness


def _session_of(state: SchedState, rid):
    for _, q in state.queues:
        for e in q:
            if e.req.rid == rid:
                return e.req.session
    for e in state.fifo:
        if e.rid == rid:
            return e.session
    return None


def _span_of(state: SchedState, rid) -> int:
    for _, q in state.queues:
        for e in q:
            if e.req.rid == rid:
                return e.req.span
    for e in state.fifo:
        if e.rid == rid:
            return e.span
    return 1


def _blocks_of(state: SchedState, rid) -> Tuple:
    for _, q in state.queues:
        for e in q:
            if e.req.rid == rid:
                return e.req.blocks
    for e in state.fifo:
        if e.rid == rid:
            return e.blocks
    return ()


def _check_post(cfg: SchedConfig, pre: SchedState, post: SchedState,
                served, evicted,
                inflight: FrozenSet[object] = frozenset()) -> None:
    """I2 (skips bound), I4 (eviction/capacity), I5 (fork marks cleared)."""
    for h, q in post.queues:
        for e in q:
            if e.skips > cfg.max_skip:
                raise _Violation(
                    "I2-starvation",
                    f"rid {e.req.rid} on home {h} skipped {e.skips} waves "
                    f"(> max_skip={cfg.max_skip}): the aging floor failed")
    per_home: Dict[int, int] = {}
    for b in post.bindings:
        per_home[b.home] = per_home.get(b.home, 0) + 1
    for h, n in per_home.items():
        if n > cfg.session_capacity:
            raise _Violation(
                "I4-eviction",
                f"home {h} holds {n} bindings "
                f"(capacity {cfg.session_capacity})")
    pre_sessions = {b.session for b in pre.bindings}
    post_sessions = {b.session for b in post.bindings}
    gone = pre_sessions - post_sessions
    dropped = {b.session for b in evicted}
    if gone - dropped:
        raise _Violation("I4-eviction",
                         f"binding(s) {sorted(gone - dropped)} vanished "
                         f"without an eviction record")
    # an evicted session may only reappear when a *later completion of
    # that session in the same wave* rebound it afresh — never by the
    # eviction itself relocating the cache
    rebound = dropped & post_sessions
    reestablished = {sv.session for sv in served}
    if rebound - reestablished:
        raise _Violation("I4-eviction",
                         f"evicted session(s) {sorted(rebound - reestablished)}"
                         f" still bound — eviction must drop, not migrate")
    for b in post.bindings:
        if b.session in rebound and not any(
                sv.session == b.session and sv.home == b.home
                for sv in served):
            raise _Violation(
                "I4-eviction",
                f"evicted session {b.session} rebound on home {b.home} "
                f"where no completion of it landed")
    # every wave serves all its placements, so no fork mark survives it
    # (continuous mode: marks of still-in-flight spill copies are exempt)
    if post.forked - inflight:
        raise _Violation("I5-binding-leak",
                         f"fork mark(s) {set(post.forked - inflight)} "
                         f"outlived the wave that made them")


# ---------------------------------------------------------------------------
# the lattice and its rule/CLI surface
# ---------------------------------------------------------------------------
def _cfg(owners, **kw) -> SchedConfig:
    base = dict(policy="homed", n_slots=len(owners), owners=tuple(owners),
                bytes_per_token=2, lookahead=8, max_skip=1,
                session_capacity=2, affinity_slack=1)
    base.update(kw)
    return SchedConfig(**base)


#: the full small-config lattice the certificate covers: homes <= 4,
#: slots <= 8, sessions <= 6 concurrent, spans <= 3 distinct — and
#: ``lookahead >= max_arrivals`` throughout, so the formation windows see
#: every queued entry and I3's conservation claim is unconditional.
DEFAULT_LATTICE: Tuple[LatticeEntry, ...] = (
    LatticeEntry("fifo-2x2", _cfg((0, 0, 1, 1), policy="fifo"),
                 max_arrivals=5, spans=(1, 2), max_sessions=2),
    LatticeEntry("homed-1x2", _cfg((0, 0)),
                 max_arrivals=5, spans=(1, 2), max_sessions=2),
    LatticeEntry("homed-2x1", _cfg((0, 1)),
                 max_arrivals=5, spans=(1, 2), max_sessions=2),
    LatticeEntry("homed-2x2", _cfg((0, 0, 1, 1)),
                 max_arrivals=5, spans=(1, 2, 3), max_sessions=2),
    LatticeEntry("homed-3x1", _cfg((0, 1, 2)),
                 max_arrivals=5, spans=(1, 2), max_sessions=3),
    LatticeEntry("homed-evict", _cfg((0, 1), session_capacity=1),
                 max_arrivals=5, spans=(1, 2), max_sessions=3),
    LatticeEntry("homed-pods-4x2",
                 _cfg((0, 0, 1, 1, 2, 2, 3, 3), homes_per_pod=2),
                 max_arrivals=4, spans=(1, 3), max_sessions=3),
    # paged entries (page_capacity > 0): I8 joins the certificate —
    # sessioned arrivals carry a (session, i) block chain, so a session's
    # return is the radix-hit case and two sessions contend for pages
    LatticeEntry("homed-paged", _cfg((0, 1), page_capacity=2),
                 max_arrivals=4, spans=(1, 2), max_sessions=2,
                 blocks_per_req=1),
    LatticeEntry("homed-paged-evict", _cfg((0, 1), page_capacity=1),
                 max_arrivals=4, spans=(1, 2), max_sessions=2,
                 blocks_per_req=2),
    LatticeEntry("fifo-paged", _cfg((0, 1), policy="fifo",
                                    page_capacity=2),
                 max_arrivals=4, spans=(1, 2), max_sessions=2,
                 blocks_per_req=1),
    # continuous refill: the mid-wave free-subset formation + per-slot
    # finish alphabet of the paged server loop, pages pinned across
    # overlapping request lifetimes
    LatticeEntry("homed-cont-2x1", _cfg((0, 1), page_capacity=2),
                 max_arrivals=3, spans=(1, 2), max_sessions=2,
                 blocks_per_req=1, continuous=True),
)


#: the cheap corner of the lattice `check_decode` runs per target; the
#: CLI certificate and the certification test always sweep the full one
FAST_LATTICE: Tuple[LatticeEntry, ...] = tuple(
    e for e in DEFAULT_LATTICE
    if e.name in ("fifo-2x2", "homed-2x1", "homed-evict",
                  "homed-pods-4x2", "homed-paged", "homed-cont-2x1"))

_cert_cache: Dict[Tuple[LatticeEntry, ...], Dict] = {}


def certify_lattice(lattice: Sequence[LatticeEntry] = DEFAULT_LATTICE
                    ) -> Dict:
    """The scheduler certificate the CLI prints and `run.py` stamps:
    ``{entry: {"states": N, "witness": None | Witness}}``.  Memoized per
    lattice (the transitions are pure), so one process pays once."""
    key = tuple(lattice)
    if key in _cert_cache:
        return _cert_cache[key]
    out: Dict = {}
    for entry in lattice:
        witness, states = certify(entry)
        out[entry.name] = {"states": states, "witness": witness,
                           "cfg": entry.cfg}
    _cert_cache[key] = out
    return out


def r9_scheduler_certification(report: Report,
                               lattice: Sequence[LatticeEntry]
                               = DEFAULT_LATTICE) -> None:
    """Run R9: certify the transition functions over the lattice; any
    witness is an ERROR carrying the minimal violating event script."""
    cert = certify_lattice(tuple(lattice))
    bad = {n: rec for n, rec in cert.items() if rec["witness"] is not None}
    for name, rec in bad.items():
        w: Witness = rec["witness"]
        report.add(Finding(
            "R9", Severity.ERROR, "scheduler",
            message=f"{w.invariant} violated — {w.format()}"))
    if not bad:
        total = sum(rec["states"] for rec in cert.values())
        report.notes.append(
            f"R9: scheduler certified — I1-I8 hold over {len(cert)} "
            f"lattice configs, {total} canonical states explored "
            f"exhaustively")
