"""Module-header facts of a partitioned HLO program.

The rules need two facts the op-level parser (`launch.hlo_cost`) doesn't
extract: the entry computation's parameter/result types and the
input→output donation aliases.  Both live on the `HloModule` header line:

  HloModule jit_f, entry_computation_layout={(s32[512]{0})->s32[512]{0}},
      input_output_alias={ {}: (0, {}, may-alias) }, ...

Types may be tuples whose member layouts contain parens/braces
(`f32[8,16]{1,0:T(8,128)}`), so splitting is depth-tracked, not regex.
"""
from __future__ import annotations

import re
from typing import List, Set, Tuple

from repro.launch.hlo_cost import _parse_shape, _shape_bytes

_ALIAS_RE = re.compile(r"input_output_alias=\{(.*?)\}(?:,|\s|$)")
_ALIAS_ENTRY_RE = re.compile(r"\{[\d,\s]*\}:\s*\((\d+)")
_ENTRY_LAYOUT_RE = re.compile(r"entry_computation_layout=\{")


def _split_top(s: str, sep: str = ",") -> List[str]:
    """Split on `sep` at paren/brace/bracket depth 0."""
    out, buf, depth = [], [], 0
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == sep and depth == 0:
            out.append("".join(buf).strip())
            buf = []
        else:
            buf.append(ch)
    tail = "".join(buf).strip()
    if tail:
        out.append(tail)
    return out


def _balanced(text: str, start: int, open_ch: str = "{",
              close_ch: str = "}") -> str:
    """The balanced `{...}` region starting at text[start] (inclusive)."""
    assert text[start] == open_ch, text[start:start + 20]
    depth = 0
    for i in range(start, len(text)):
        if text[i] == open_ch:
            depth += 1
        elif text[i] == close_ch:
            depth -= 1
            if depth == 0:
                return text[start:i + 1]
    raise ValueError("unbalanced header region")


def entry_layout(text: str) -> Tuple[List[str], List[str]]:
    """(param type strings, output type strings) of the entry computation.

    A tuple-typed result is flattened to its members; a single result is a
    one-element list.  Empty lists when the header carries no layout.
    """
    m = _ENTRY_LAYOUT_RE.search(text)
    if not m:
        return [], []
    region = _balanced(text, m.end() - 1)[1:-1]          # strip outer {}
    if "->" not in region:
        return [], []
    params_s, out_s = region.split("->", 1)
    params_s = params_s.strip()
    if params_s.startswith("(") and params_s.endswith(")"):
        params_s = params_s[1:-1]
    params = [p for p in _split_top(params_s) if p]
    out_s = out_s.strip()
    if out_s.startswith("(") and out_s.endswith(")"):
        outs = [o for o in _split_top(out_s[1:-1]) if o]
    else:
        outs = [out_s] if out_s else []
    return params, outs


def aliased_param_indices(text: str) -> Set[int]:
    """Parameter indices donated to an output (input_output_alias header)."""
    m = re.search(r"input_output_alias=\{", text)
    if not m:
        return set()
    region = _balanced(text, m.end() - 1)
    return {int(i) for i in _ALIAS_ENTRY_RE.findall(region)}


def type_key(type_str: str) -> Tuple[Tuple[str, Tuple[int, ...]], ...]:
    """Layout-insensitive identity of a type: ((dtype, dims), ...)."""
    return tuple((d, tuple(dims)) for d, dims in _parse_shape(type_str))


def type_bytes(type_str: str) -> float:
    return _shape_bytes(type_str)
