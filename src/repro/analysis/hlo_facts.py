"""Module-header and buffer-liveness facts of a partitioned HLO program.

The rules need facts the op-level parser (`launch.hlo_cost`) doesn't
extract: the entry computation's parameter/result types, the input→output
donation aliases, and (for R10) a linear-scan liveness estimate of peak
live HBM bytes.  The header facts live on the `HloModule` header line:

  HloModule jit_f, entry_computation_layout={(s32[512]{0})->s32[512]{0}},
      input_output_alias={ {}: (0, {}, may-alias) }, ...

Types may be tuples whose member layouts contain parens/braces
(`f32[8,16]{1,0:T(8,128)}`), so splitting is depth-tracked, not regex.

The liveness scan (`liveness`) walks the entry computation's ops in program
order, opening a buffer at each defining op and closing it after its last
top-level use; parameters stay live for the whole call (XLA keeps argument
buffers resident), and the ROOT's feeding values stay live to the end.
Pure shape-aliasing ops (tuple / get-tuple-element / bitcast / constant)
allocate nothing.  Fusion and while internals are not descended into —
their scratch is `temp` in XLA's own accounting and is covered when the
caller passes `compiled.memory_analysis()` figures alongside; the scan is
an order-of-magnitude floor, deliberately conservative in the *over*
direction for donated buffers (both sides of an alias are counted).
"""
from __future__ import annotations

import re
from typing import Dict, List, Set, Tuple

from repro.launch.hlo_cost import (_parse_shape, _shape_bytes, parse_module)

_ALIAS_RE = re.compile(r"input_output_alias=\{(.*?)\}(?:,|\s|$)")
_ALIAS_ENTRY_RE = re.compile(r"\{[\d,\s]*\}:\s*\((\d+)")
_ENTRY_LAYOUT_RE = re.compile(r"entry_computation_layout=\{")


def _split_top(s: str, sep: str = ",") -> List[str]:
    """Split on `sep` at paren/brace/bracket depth 0."""
    out, buf, depth = [], [], 0
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == sep and depth == 0:
            out.append("".join(buf).strip())
            buf = []
        else:
            buf.append(ch)
    tail = "".join(buf).strip()
    if tail:
        out.append(tail)
    return out


def _balanced(text: str, start: int, open_ch: str = "{",
              close_ch: str = "}") -> str:
    """The balanced `{...}` region starting at text[start] (inclusive)."""
    assert text[start] == open_ch, text[start:start + 20]
    depth = 0
    for i in range(start, len(text)):
        if text[i] == open_ch:
            depth += 1
        elif text[i] == close_ch:
            depth -= 1
            if depth == 0:
                return text[start:i + 1]
    raise ValueError("unbalanced header region")


def entry_layout(text: str) -> Tuple[List[str], List[str]]:
    """(param type strings, output type strings) of the entry computation.

    A tuple-typed result is flattened to its members; a single result is a
    one-element list.  Empty lists when the header carries no layout.
    """
    m = _ENTRY_LAYOUT_RE.search(text)
    if not m:
        return [], []
    region = _balanced(text, m.end() - 1)[1:-1]          # strip outer {}
    if "->" not in region:
        return [], []
    params_s, out_s = region.split("->", 1)
    params_s = params_s.strip()
    if params_s.startswith("(") and params_s.endswith(")"):
        params_s = params_s[1:-1]
    params = [p for p in _split_top(params_s) if p]
    out_s = out_s.strip()
    if out_s.startswith("(") and out_s.endswith(")"):
        outs = [o for o in _split_top(out_s[1:-1]) if o]
    else:
        outs = [out_s] if out_s else []
    return params, outs


def aliased_param_indices(text: str) -> Set[int]:
    """Parameter indices donated to an output (input_output_alias header)."""
    m = re.search(r"input_output_alias=\{", text)
    if not m:
        return set()
    region = _balanced(text, m.end() - 1)
    return {int(i) for i in _ALIAS_ENTRY_RE.findall(region)}


# Ops that reuse (or trivially materialize) existing storage: no new HBM
# buffer is opened for them in the liveness scan.
_ALIAS_OPCODES = {"tuple", "get-tuple-element", "bitcast", "constant",
                  "after-all", "partition-id", "replica-id", "copy-done",
                  "all-reduce-done", "all-gather-done"}

_NAME_RE = re.compile(r"%([\w.\-]+)")


def _value_operands(op, shapes: Dict[str, str]) -> List[str]:
    """Names of same-computation values an op line consumes.

    Scans every `%name` after the opcode's open paren and keeps the ones
    defined in this computation — computation references (`calls=%fc`,
    `body=%b`) are filtered out because they are not in the value table.
    """
    parts = op.line.split(op.opcode + "(", 1)
    if len(parts) < 2:
        return []
    return [n for n in _NAME_RE.findall(parts[1]) if n in shapes]


def liveness(text: str) -> Dict:
    """Linear-scan peak-live-bytes estimate over the entry computation.

    Returns {"peak_bytes", "peak_index", "param_bytes", "n_buffers",
    "live_at_peak": [(bytes, name, opcode), ...] (largest first, capped)}.
    """
    entry = parse_module(text)["__entry__"]
    ops = entry.ops
    n = len(ops)
    last_use: Dict[str, int] = {}
    for i, op in enumerate(ops):
        for name in _value_operands(op, entry.shapes):
            last_use[name] = i
    root_i = next((i for i, op in enumerate(ops) if "ROOT" in op.line), n - 1)

    # (start, end, bytes, name, opcode); end is the last index the buffer
    # is live at (inclusive).
    records: List[Tuple[int, int, float, str, str]] = []
    param_bytes = 0.0
    for i, op in enumerate(ops):
        if op.opcode in _ALIAS_OPCODES:
            continue
        b = _shape_bytes(op.result)
        if b <= 0:
            continue
        if op.opcode == "parameter":
            records.append((0, n - 1, b, op.name, op.opcode))
            param_bytes += b
            continue
        end = last_use.get(op.name, i)
        if i == root_i or last_use.get(op.name, -1) >= root_i:
            end = n - 1                       # feeds the result: live to end
        records.append((i, end, b, op.name, op.opcode))

    delta = [0.0] * (n + 1)
    for start, end, b, _, _ in records:
        delta[start] += b
        delta[end + 1] -= b
    peak, peak_i, run = 0.0, 0, 0.0
    for i in range(n):
        run += delta[i]
        if run > peak:
            peak, peak_i = run, i
    at_peak = sorted(
        ((b, name, opcode) for start, end, b, name, opcode in records
         if start <= peak_i <= end), reverse=True)
    return {"peak_bytes": peak, "peak_index": peak_i,
            "param_bytes": param_bytes, "n_buffers": len(records),
            "live_at_peak": at_peak[:8]}


def type_key(type_str: str) -> Tuple[Tuple[str, Tuple[int, ...]], ...]:
    """Layout-insensitive identity of a type: ((dtype, dims), ...)."""
    return tuple((d, tuple(dims)) for d, dims in _parse_shape(type_str))


def type_bytes(type_str: str) -> float:
    return _shape_bytes(type_str)
