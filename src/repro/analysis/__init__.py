"""homecheck — static locality analysis for compiled workloads.

Proves, before anything runs, that a lowered program respects its
cache-home contract:

  R1 surprise-collective   HLO collectives == exchange_schedule's budget
  R2 home-leak             device groups never span undeclared mesh axes
  R3 vmem-budget           pallas_call footprints fit per-core VMEM
  R4 donation-audit        large step-carried buffers are donated

Entry points: `Locale.check(...)` (repro.core.api), `check_workload` /
`check_decode` / `check_artifacts` here, and the `launch/homecheck.py`
CLI.  See README "Static analysis".
"""
from repro.analysis.findings import (RULES, Finding, Report, Severity,
                                     summarize)
from repro.analysis.homecheck import (check_artifacts, check_decode,
                                      check_workload)

__all__ = ["Finding", "Report", "Severity", "RULES", "summarize",
           "check_artifacts", "check_decode", "check_workload"]
