"""homecheck — static locality analysis for compiled workloads.

Proves, before anything runs, that a lowered program respects its
cache-home contract:

  R1 surprise-collective   HLO collectives == exchange_schedule's budget
  R2 home-leak             device groups never span undeclared mesh axes
  R3 vmem-budget           pallas_call footprints fit per-core VMEM
  R4 donation-audit        large step-carried buffers are donated
  R5 write-race/coverage   pallas_call block images partition each output
  R6 network-certification exchange network 0-1-certified as a sorter
  R7 index-arithmetic      merge-path ranks fit; BIG sentinel tie-stable
  R8 grid-dead-lane        no pl.when lane that never executes
  R9 scheduler-certification  serving-scheduler invariants I1-I7 proved
                           exhaustively over the small-config lattice
  R10 hbm-live-range       peak live HBM bytes fit the per-device ceiling
  R11 collective-control-flow  no collective under data-dependent control
                           flow or with branch-inconsistent ordering

Entry points: `Locale.check(...)` (repro.core.api), `check_workload` /
`check_decode` / `check_artifacts` here, and the `launch/homecheck.py`
CLI (``--rules`` selects a subset).  See README "Static analysis".
"""
from repro.analysis.findings import (RULES, Finding, Report, Severity,
                                     normalize_rules, summarize)
from repro.analysis.homecheck import (check_artifacts, check_decode,
                                      check_workload)
from repro.analysis.netverify import (certify_supported_meshes,
                                      zero_one_certify)
from repro.analysis.schedcheck import (DEFAULT_LATTICE, FAST_LATTICE,
                                       certify_lattice)

__all__ = ["Finding", "Report", "Severity", "RULES", "normalize_rules",
           "summarize", "check_artifacts", "check_decode", "check_workload",
           "certify_supported_meshes", "zero_one_certify",
           "DEFAULT_LATTICE", "FAST_LATTICE", "certify_lattice"]
