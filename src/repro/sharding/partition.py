"""Static partitioning rules — the 'static mapping' leg of the paper's technique.

Every parameter and activation layout is chosen explicitly here; nothing is
left to the runtime. This mirrors the paper's static thread->core pinning
(Algorithm 1, step 3): placement decisions are made once, up front, and the
lowered HLO is the proof of where data lives.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """Resolved placement plan for one (arch x shape x mesh) cell."""

    mesh: Optional[Mesh]
    dp: Tuple[str, ...] = ()          # data-parallel axes, e.g. ("pod", "data")
    tp: Optional[str] = None          # tensor-parallel axis ("model")
    # resolved per-cell decisions (None == replicate on that dim):
    batch_axes: Optional[Tuple[str, ...]] = None
    seq_axis: Optional[str] = None    # SP axis for the residual stream
    head_axis: Optional[str] = None
    kv_axis: Optional[str] = None
    expert_axis: Optional[str] = None
    fsdp_axes: Optional[Tuple[str, ...]] = None
    cache_seq_axis: Optional[object] = None  # str | tuple | None
    zero1_axes: Optional[Tuple[str, ...]] = None

    def axis_size(self, name) -> int:
        if self.mesh is None or name is None:
            return 1
        if isinstance(name, tuple):
            return math.prod(self.mesh.shape[a] for a in name)
        return self.mesh.shape[name]

    @property
    def dp_size(self) -> int:
        return self.axis_size(self.dp) if self.dp else 1

    @property
    def tp_size(self) -> int:
        return self.axis_size(self.tp) if self.tp else 1

    def sharding(self, *spec_dims) -> NamedSharding:
        assert self.mesh is not None
        return NamedSharding(self.mesh, P(*spec_dims))


NULL_PLAN = MeshPlan(mesh=None)


def ws(x, plan: MeshPlan, *spec_dims):
    """with_sharding_constraint that degrades to a no-op without a mesh."""
    if plan is None or plan.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(plan.mesh, P(*spec_dims)))


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def make_plan(mesh: Optional[Mesh], cfg: ArchConfig, shape: ShapeSpec) -> MeshPlan:
    """Resolve the static placement plan for one cell."""
    if mesh is None:
        return NULL_PLAN
    names = mesh.axis_names
    dp = tuple(a for a in names if a != "model")
    tp = "model"
    tp_n = math.prod(mesh.shape[a] for a in [tp])
    dp_n = math.prod(mesh.shape[a] for a in dp)

    batch_axes = dp if _div(shape.global_batch, dp_n) else None
    seq_len = shape.seq_len if shape.kind != "decode" else 1
    seq_axis = (tp if (cfg.parallel.sequence_shard and _div(seq_len, tp_n)
                       and shape.kind != "decode") else None)
    head_axis = tp if _div(cfg.num_heads, tp_n) else None
    kv_axis = tp if _div(cfg.num_kv_heads, tp_n) else None
    expert_axis = tp if _div(cfg.num_experts, tp_n) else None
    # (mixtral iter3 tried dropping FSDP at inference: collective barely moved
    # — the big psum is the TP down-proj reduce, not FSDP — while the f32
    # expert buffers blew HBM 11->29GB. Refuted; FSDP stays whenever enabled.)
    fsdp_axes = dp if (cfg.parallel.fsdp and _div(cfg.d_model, dp_n)) else None
    zero1_axes = dp if (cfg.parallel.zero1 and _div(cfg.d_model, dp_n)) else None

    # decode-time KV cache layout (see DESIGN.md §5)
    cache_len = min(shape.seq_len, cfg.sliding_window) if cfg.sliding_window else shape.seq_len
    cache_seq_axis = None
    if shape.kind == "decode":
        if batch_axes is None and cache_len >= 8192:
            cache_seq_axis = "data" if "data" in names else None  # context parallel
        elif kv_axis is None and cache_len >= 8192:
            cache_seq_axis = tp
    return MeshPlan(mesh=mesh, dp=dp, tp=tp, batch_axes=batch_axes,
                    seq_axis=seq_axis, head_axis=head_axis, kv_axis=kv_axis,
                    expert_axis=expert_axis, fsdp_axes=fsdp_axes,
                    cache_seq_axis=cache_seq_axis, zero1_axes=zero1_axes)


# ---------------------------------------------------------------------------
# parameter partition specs (path-name driven)
# ---------------------------------------------------------------------------
def _leaf_spec(name: str, ndim: int, plan: MeshPlan, cfg: ArchConfig) -> P:
    tp, f = plan.tp, plan.fsdp_axes
    h, kv, e = plan.head_axis, plan.kv_axis, plan.expert_axis
    table = {
        # embeddings / head
        "tok_embed": P(tp, f),            # (Vp, D)
        "head_w": P(f, tp),               # (D, Vp)
        # attention (D,H,hd)/(H,hd,D)
        "wq": P(f, h, None),
        "wk": P(f, kv, None),
        "wv": P(f, kv, None),
        "wo": P(h, None, f),
        # dense mlp
        "w_gate": P(f, tp),
        "w_up": P(f, tp),
        "w_down": P(tp, f),
        # moe (E,D,F)/(E,F,D): EP when E divides, else TP on F
        "we_gate": P(e, f, None) if e else P(None, f, tp),
        "we_up": P(e, f, None) if e else P(None, f, tp),
        "we_down": P(e, None, f) if e else P(None, tp, f),
        "router": P(None, None),
        # mamba2
        "wz": P(f, tp),
        "wx": P(f, tp),
        "wBC": P(f, None),
        "wdt": P(f, tp),
        "conv_x": P(None, tp),
        "conv_bc": P(None, None),
        "out_proj": P(tp, f),
    }
    if name in table:
        spec = table[name]
        # trim/extend to leaf rank (vectors like scales fall through below)
        if len(spec) == ndim:
            return spec
    # norms, biases, A_log, dt_bias, D_skip, q/k norm scales: replicate
    return P(*([None] * ndim))


def param_specs(params_shape, plan: MeshPlan, cfg: ArchConfig):
    """Build a PartitionSpec pytree matching a parameter (shape-)pytree.

    Leaves under the 'stack' subtree carry a leading superblock axis ->
    their spec gets a None prepended.
    """
    def spec_for(path, leaf) -> P:
        keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        name = keys[-1]
        stacked = "stack" in keys
        ndim = len(leaf.shape) - (1 if stacked else 0)
        spec = _leaf_spec(name, ndim, plan, cfg)
        if stacked:
            spec = P(None, *spec)
        return spec

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


def shardings_for(tree, plan: MeshPlan, cfg: ArchConfig):
    specs = param_specs(tree, plan, cfg)
    return jax.tree.map(lambda s: NamedSharding(plan.mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))


def batch_specs(batch_struct, plan: MeshPlan):
    """Chunk-contiguous 'local homing' layout: batch dim owned per-device."""
    def spec(_path, leaf):
        b = plan.batch_axes
        return P(b, *([None] * (len(leaf.shape) - 1)))
    return jax.tree_util.tree_map_with_path(spec, batch_struct)


def cache_specs(cache_struct, plan: MeshPlan, cfg: ArchConfig):
    """Decode-cache layout (see DESIGN.md §5)."""
    b = plan.batch_axes
    tp = plan.tp
    kv = plan.kv_axis
    cseq = plan.cache_seq_axis
    hs_ax = tp if (cfg.ssm_nheads and cfg.ssm_nheads % plan.tp_size == 0) else None
    di_ax = tp if (cfg.d_inner and cfg.d_inner % max(plan.tp_size, 1) == 0) else None

    def spec(path, leaf):
        keys = [getattr(k, "key", str(k)) for k in path]
        name = keys[-1]
        nd = len(leaf.shape)
        if name in ("k", "v"):
            is_cross = leaf.shape[2] == cfg.num_image_tokens and cfg.num_image_tokens
            s_ax = None if is_cross else cseq
            return P(None, b, s_ax, kv, None)
        if name == "kpos":
            # self-attn kpos is per-row (nsb, B, Sc) since the per-slot
            # position clocks; cross kpos stays shared (nsb, n_img)
            return P(None, b, cseq) if nd == 3 else P(None, cseq)
        if name == "ssm":
            return P(None, b, hs_ax, None, None)
        if name == "conv_x":
            return P(None, b, None, di_ax)
        if name == "conv_bc":
            return P(None, b, None, None)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(spec, cache_struct)


def opt_specs(params_struct, plan: MeshPlan, cfg: ArchConfig):
    """Optimizer-state specs: m/v/ef mirror the params; ZeRO-1 additionally
    shards any dp-free leading dim over the dp axes where it divides."""
    pspecs = param_specs(params_struct, plan, cfg)

    def zero1(spec: P, leaf):
        if plan.zero1_axes is None:
            return spec
        dims = list(spec) + [None] * (len(leaf.shape) - len(spec))
        dp_n = plan.axis_size(plan.zero1_axes)
        for i, (d, sh) in enumerate(zip(dims, leaf.shape)):
            used = set()
            for dd in dims:
                for a in (dd if isinstance(dd, tuple) else (dd,)):
                    used.add(a)
            if d is None and sh % dp_n == 0 and not set(plan.zero1_axes) & used:
                dims[i] = plan.zero1_axes
                break
        return P(*dims)

    mv = jax.tree.map(zero1, pspecs, params_struct)
    return {"adam": {"m": mv, "v": mv, "step": P()}}


def full_opt_specs(opt_struct, params_struct, plan: MeshPlan, cfg: ArchConfig):
    """Spec tree matching init_opt_state's structure exactly."""
    base = opt_specs(params_struct, plan, cfg)
    out = {"adam": base["adam"]}
    if "ef" in opt_struct:
        out["ef"] = param_specs(params_struct, plan, cfg)
    return out
