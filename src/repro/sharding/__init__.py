from repro.sharding.partition import (MeshPlan, NULL_PLAN, make_plan,
                                      param_specs, ws)

__all__ = ["MeshPlan", "NULL_PLAN", "make_plan", "param_specs", "ws"]
