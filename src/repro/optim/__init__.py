from repro.optim.adamw import AdamW
from repro.optim.schedule import cosine_schedule
from repro.optim.compression import (compress_tree, decompress_tree,
                                     error_feedback_update, compressed_psum)

__all__ = ["AdamW", "cosine_schedule", "compress_tree", "decompress_tree",
           "error_feedback_update", "compressed_psum"]
