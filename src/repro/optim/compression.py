"""Gradient compression: int8 block quantisation + error feedback.

Rationale (DESIGN.md §5): the cross-pod data-parallel axis is the slow link
(DCN/ICI-limited); quantising gradient traffic to int8 cuts its collective
bytes 4x. `compressed_psum` is the shard_map building block (all-gather of
int8 payloads + local dequant-reduce — wire format is genuinely 1 byte per
element); `error_feedback_update` keeps the quantisation bias from
accumulating across steps (property-tested for convergence).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(x, block: int = 256):
    """Symmetric per-block int8 quantisation. Returns (q int8, scales f32)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def dequantize(q, scale, shape):
    deq = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    return deq[: int(jnp.prod(jnp.array(shape)))].reshape(shape)


def compress_tree(tree, block: int = 256):
    return jax.tree.map(lambda x: quantize(x, block), tree,
                        is_leaf=lambda x: isinstance(x, jnp.ndarray))


def decompress_tree(ctree, shapes_tree):
    return jax.tree.map(lambda qs, ref: dequantize(qs[0], qs[1], ref.shape),
                        ctree, shapes_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def error_feedback_update(grad, ef, block: int = 256):
    """Quantise (grad + ef); return (dequantised grad, new ef residual)."""
    g = grad.astype(jnp.float32) + ef
    q, s = quantize(g, block)
    deq = dequantize(q, s, g.shape)
    return deq.astype(grad.dtype), g - deq


def compressed_psum(x, axis_name: str, block: int = 256):
    """int8-wire psum for use inside shard_map.

    Wire format: each shard contributes an int8 payload + f32 per-block
    scales; the all-gather moves 1 byte/element instead of 4. Exact sum is
    recovered up to quantisation error (bounded by scale/2 per element).
    """
    q, s = quantize(x, block)
    qg = jax.lax.all_gather(q, axis_name)          # (n, blocks, block) int8 wire
    sg = jax.lax.all_gather(s, axis_name)          # (n, blocks) f32 (tiny)
    deq = qg.astype(jnp.float32) * sg[..., None]
    total = jnp.sum(deq, axis=0).reshape(-1)
    return total[: x.size].reshape(x.shape).astype(x.dtype)
