"""AdamW with fp32 state over (possibly bf16) params + global-norm clipping."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamW:
    lr: Callable[[jnp.ndarray], jnp.ndarray] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.float32(self.lr)

    def update(self, grads, state, params):
        step = state["step"] + 1
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if self.clip_norm:
            gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                                 for g in jax.tree.leaves(g32)))
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            g32 = jax.tree.map(lambda g: g * scale, g32)
        else:
            gnorm = jnp.float32(0.0)
        m = jax.tree.map(lambda m_, g: self.b1 * m_ + (1 - self.b1) * g,
                         state["m"], g32)
        v = jax.tree.map(lambda v_, g: self.b2 * v_ + (1 - self.b2) * g * g,
                         state["v"], g32)
        bc1 = 1 - self.b1 ** step.astype(jnp.float32)
        bc2 = 1 - self.b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, m_, v_):
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, {"m": m, "v": v, "step": step}, gnorm
