"""Shared model building blocks: init helpers, RMSNorm, RoPE, SwiGLU MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def dt(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def pdt(cfg: ArchConfig):
    return jnp.dtype(cfg.param_dtype)


def ninit(key, shape, dtype, scale: float = 0.02):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rmsnorm(x, scale, eps: float):
    """RMSNorm in fp32, cast back to input dtype."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def gated_rmsnorm(x, gate, scale, eps: float):
    """Mamba2-style: norm(x * silu(gate))."""
    return rmsnorm(x * jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype),
                   scale, eps)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------
def rope(x, positions, theta: float):
    """Apply rotary embedding. x: (..., S, H, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32)
                    * (jnp.log(theta) / half))                 # (half,)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    # broadcast over heads: (..., S, 1, half)
    angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------
def init_mlp(key, cfg: ArchConfig, d_ff: int | None = None):
    D, F = cfg.d_model, d_ff or cfg.d_ff
    kg, ku, kd = jax.random.split(key, 3)
    scale_out = 0.02 / max(1.0, (2 * cfg.num_layers) ** 0.5) * 50  # mild depth scaling
    return {
        "w_gate": ninit(kg, (D, F), pdt(cfg)),
        "w_up": ninit(ku, (D, F), pdt(cfg)),
        "w_down": ninit(kd, (F, D), pdt(cfg), scale_out),
    }


def apply_mlp(p, x, cfg: ArchConfig, plan=None):
    from repro.sharding.partition import ws
    b = plan.batch_axes if plan else None
    tpax = plan.tp if plan else None
    g = x @ p["w_gate"].astype(x.dtype)
    u = x @ p["w_up"].astype(x.dtype)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = ws(h, plan, b, None, tpax)
    return h @ p["w_down"].astype(x.dtype)


def causal_conv1d(u, w, state=None):
    """Depth-wise causal conv. u: (B, S, C); w: (K, C).

    Returns (y, new_state) where state holds the trailing K-1 inputs.
    """
    K = w.shape[0]
    if state is not None:
        u_ext = jnp.concatenate([state.astype(u.dtype), u], axis=1)
    else:
        u_ext = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(u_ext[:, j:j + u.shape[1], :] * w[j].astype(u.dtype)
            for j in range(K))
    new_state = u_ext[:, -(K - 1):, :] if K > 1 else jnp.zeros_like(u[:, :0])
    return y, new_state
