"""Pure step functions: train / prefill / decode.

These close over (model, plan, optimizer) and are what `launch/dryrun.py`
lowers and `runtime/trainer.py` executes.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.model import LM
from repro.optim.adamw import AdamW
from repro.optim.compression import error_feedback_update
from repro.sharding.partition import (MeshPlan, NULL_PLAN, param_specs, ws)

AUX_WEIGHT = 0.01


def _constrain_like_params(tree, params, plan: MeshPlan, cfg):
    """Pin gradients/accumulators to the parameter layout.

    Without this the microbatch grad accumulator has no layout and XLA is
    free to replicate f32 gradients across the data axes (for FSDP'd params
    that is dp_size x the memory and an all-reduce instead of a
    reduce-scatter). Perf iteration #1 in EXPERIMENTS.md §Perf.
    """
    if plan is None or plan.mesh is None:
        return tree
    import jax as _jax
    from jax.sharding import NamedSharding
    specs = param_specs(params, plan, cfg)
    return _jax.tree.map(
        lambda x, s: _jax.lax.with_sharding_constraint(
            x, NamedSharding(plan.mesh, s)), tree, specs)


def make_loss_fn(model: LM, cfg: ArchConfig, plan: MeshPlan):
    V, Vp = cfg.vocab_size, cfg.vocab_padded

    def loss_fn(params, batch):
        logits, _, aux = model.forward(params, batch, plan)
        lf = logits.astype(jnp.float32)
        iota = jnp.arange(Vp)
        lf = jnp.where(iota < V, lf, -1e30)
        lse = jax.scipy.special.logsumexp(lf, axis=-1)
        tl = jnp.sum(jnp.where(iota == batch["targets"][..., None], lf, 0.0),
                     axis=-1)
        nll = jnp.mean(lse - tl)
        return nll + AUX_WEIGHT * aux, nll

    return loss_fn


def _micro_split(batch, m: int, plan: MeshPlan):
    """(GB, ...) -> (m, GB/m, ...) with an explicit post-reshape layout."""
    def split(x):
        assert x.shape[0] % m == 0, (x.shape, m)
        y = x.reshape(m, x.shape[0] // m, *x.shape[1:])
        b_ax = plan.batch_axes if plan else None
        return ws(y, plan, None, b_ax, *([None] * (y.ndim - 2)))
    return jax.tree.map(split, batch)


def make_train_step(model: LM, cfg: ArchConfig, plan: MeshPlan,
                    optimizer: AdamW):
    loss_fn = make_loss_fn(model, cfg, plan)
    M = max(cfg.parallel.microbatches, 1)
    accum_dtype = jnp.dtype(cfg.parallel.accum_dtype)

    def train_step(params, opt_state, batch):
        if M == 1:
            (loss, nll), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
            grads = _constrain_like_params(grads, params, plan, cfg)
        elif cfg.parallel.accum_via_scan_grad:
            # grad-of-scan: autodiff accumulates parameter grads across the
            # microbatch loop internally -> one cross-dp reduction per step
            mb = _micro_split(batch, M, plan)

            def total_loss(params):
                def body(carry, one):
                    l, nll = loss_fn(params, one)
                    return carry + l / M, nll
                tot, nlls = jax.lax.scan(
                    jax.checkpoint(body, prevent_cse=False),
                    jnp.float32(0.0), mb)
                return tot, jnp.mean(nlls)

            (loss, nll), grads = jax.value_and_grad(
                total_loss, has_aux=True)(params)
            grads = _constrain_like_params(grads, params, plan, cfg)
        else:
            mb = _micro_split(batch, M, plan)

            def body(acc, one):
                (l, nll), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, one)
                g = _constrain_like_params(g, params, plan, cfg)
                acc = jax.tree.map(lambda a, gg: a + gg.astype(accum_dtype),
                                   acc, g)
                acc = _constrain_like_params(acc, params, plan, cfg)
                return acc, nll
            acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype),
                                params)
            acc0 = _constrain_like_params(acc0, params, plan, cfg)
            grads, nlls = jax.lax.scan(body, acc0, mb)
            grads = jax.tree.map(lambda g: g / M, grads)
            nll = jnp.mean(nlls)

        if cfg.parallel.grad_compression and "ef" in opt_state:
            pairs = jax.tree.map(error_feedback_update, grads,
                                 opt_state["ef"])
            grads = jax.tree.map(lambda p: p[0], pairs,
                                 is_leaf=lambda x: isinstance(x, tuple))
            new_ef = jax.tree.map(lambda p: p[1], pairs,
                                  is_leaf=lambda x: isinstance(x, tuple))
        else:
            new_ef = opt_state.get("ef")

        new_params, new_adam, gnorm = optimizer.update(grads,
                                                       opt_state["adam"], params)
        new_opt = {"adam": new_adam}
        if new_ef is not None:
            new_opt["ef"] = new_ef
        metrics = {"loss": nll, "grad_norm": gnorm,
                   "step": new_adam["step"].astype(jnp.float32)}
        return new_params, new_opt, metrics

    return train_step


def init_opt_state(cfg: ArchConfig, optimizer: AdamW, params):
    state = {"adam": optimizer.init(params)}
    if cfg.parallel.grad_compression:
        state["ef"] = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                   params)
    return state


def make_prefill_step(model: LM, cfg: ArchConfig, plan: MeshPlan):
    def prefill_step(params, batch):
        return model.prefill(params, batch, plan)
    return prefill_step


def make_decode_step(model: LM, cfg: ArchConfig, plan: MeshPlan):
    def decode_step(params, caches, batch, pos):
        return model.decode_step(params, caches, batch, pos, plan)
    return decode_step
