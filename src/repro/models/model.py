"""LM: embedding/frontend + superblock stack + head; train/prefill/decode."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import blocks
from repro.models.common import ninit, pdt, rmsnorm
from repro.sharding.partition import MeshPlan, NULL_PLAN, ws


class LM:
    """A decoder-only LM over tokens or precomputed frontend embeddings."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ init
    def init(self, key):
        cfg = self.cfg
        ke, kh, ks = jax.random.split(key, 3)
        params = {"stack": blocks.init_stack(ks, cfg),
                  "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
                  "head": {"head_w": ninit(kh, (cfg.d_model, cfg.vocab_padded),
                                           pdt(cfg))}}
        if cfg.embed_input:
            params["embed"] = {"tok_embed": ninit(ke, (cfg.vocab_padded,
                                                       cfg.d_model), pdt(cfg))}
        return params

    def param_struct(self):
        """Shape-only parameter pytree (no allocation)."""
        return jax.eval_shape(self.init, jax.random.key(0))

    # ----------------------------------------------------------------- embed
    def _embed(self, params, batch, plan):
        cfg = self.cfg
        if cfg.embed_input:
            x = jnp.take(params["embed"]["tok_embed"], batch["tokens"], axis=0)
            x = x.astype(jnp.dtype(cfg.dtype))
        else:
            x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
        b_ax = plan.batch_axes if plan else None
        s_ax = plan.seq_axis if plan else None
        return ws(x, plan, b_ax, s_ax, None)

    def _head(self, params, x, plan):
        cfg = self.cfg
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = x @ params["head"]["head_w"].astype(x.dtype)
        b_ax = plan.batch_axes if plan else None
        return ws(logits, plan, b_ax, None, plan.tp if plan else None)

    # --------------------------------------------------------------- forward
    def forward(self, params, batch, plan: MeshPlan = NULL_PLAN,
                build_cache: bool = False, cache_len=None):
        """Returns (logits (B,S,Vp), caches_or_None, aux)."""
        cfg = self.cfg
        x = self._embed(params, batch, plan)
        S = x.shape[1]
        positions = jnp.arange(S, dtype=jnp.int32)
        x, caches, aux = blocks.apply_stack(
            params["stack"], x, cfg=cfg, plan=plan, positions=positions,
            img_embeds=batch.get("image_embeds"), build_cache=build_cache,
            cache_len=cache_len)
        return self._head(params, x, plan), caches, aux

    def prefill(self, params, batch, plan: MeshPlan = NULL_PLAN,
                max_len=None):
        logits, caches, _ = self.forward(params, batch, plan,
                                         build_cache=True, cache_len=max_len)
        return logits[:, -1], caches

    def decode_step(self, params, caches, batch, pos, plan: MeshPlan = NULL_PLAN):
        """One token per row; ``pos`` is a scalar (one shared clock) or a
        (B,) int32 vector of per-slot position clocks (continuous
        batching: each row decodes at its own position)."""
        cfg = self.cfg
        if cfg.embed_input:
            x = jnp.take(params["embed"]["tok_embed"], batch["tokens"], axis=0)
            x = x.astype(jnp.dtype(cfg.dtype))
        else:
            x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
        x, new_caches = blocks.decode_stack(params["stack"], caches, x, pos,
                                            cfg=cfg, plan=plan)
        logits = self._head(params, x, plan)
        return logits[:, 0], new_caches

    def decode_pages(self, params, caches, batch, positions, write_mask,
                     plan: MeshPlan = NULL_PLAN):
        """Page-stepped prefill: S new tokens per row written into the
        decode cache at ``positions`` (B, S), cache commits gated by
        ``write_mask`` (B, S) — pad positions and non-refilling rows
        compute but never write.  Page p of a row attends only to cache
        entries at positions < its own, so a page's K/V content is a pure
        function of the row's earlier pages: computed and pool-attached
        pages are interchangeable bit-for-bit.  Attention-only stacks
        (no sequential SSM state) support this path.  Returns
        (logits (B, S, Vp), caches')."""
        cfg = self.cfg
        if cfg.embed_input:
            x = jnp.take(params["embed"]["tok_embed"], batch["tokens"], axis=0)
            x = x.astype(jnp.dtype(cfg.dtype))
        else:
            x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
        x, new_caches = blocks.decode_stack(params["stack"], caches, x,
                                            positions, cfg=cfg, plan=plan,
                                            write_mask=write_mask)
        logits = self._head(params, x, plan)
        return logits, new_caches

    # ----------------------------------------------------------------- cache
    def init_cache(self, batch_size: int, max_len: int, img_len: int = 0):
        """Zero-initialised decode cache (same structure prefill builds)."""
        cfg = self.cfg
        members = blocks.superblock_spec(cfg)
        nsb = blocks.num_superblocks(cfg)
        dtype = jnp.dtype(cfg.dtype)
        Sc = min(cfg.sliding_window, max_len) if cfg.sliding_window else max_len
        KV, hd = cfg.num_kv_heads, cfg.head_dim

        def member_cache(spec):
            if spec.mixer == "mamba":
                return {
                    "ssm": jnp.zeros((nsb, batch_size, cfg.ssm_nheads,
                                      cfg.ssm_headdim, cfg.ssm_state), jnp.float32),
                    "conv_x": jnp.zeros((nsb, batch_size, cfg.ssm_conv - 1,
                                         cfg.d_inner), dtype),
                    "conv_bc": jnp.zeros((nsb, batch_size, cfg.ssm_conv - 1,
                                          2 * cfg.ssm_ngroups * cfg.ssm_state), dtype),
                }
            if spec.mixer == "cross":
                n = img_len or cfg.num_image_tokens
                return {"k": jnp.zeros((nsb, batch_size, n, KV, hd), dtype),
                        "v": jnp.zeros((nsb, batch_size, n, KV, hd), dtype),
                        "kpos": jnp.zeros((nsb, n), jnp.int32)}
            return {"k": jnp.zeros((nsb, batch_size, Sc, KV, hd), dtype),
                    "v": jnp.zeros((nsb, batch_size, Sc, KV, hd), dtype),
                    "kpos": jnp.full((nsb, batch_size, Sc), -1, jnp.int32)}

        return {f"m{i}": member_cache(m) for i, m in enumerate(members)}

    def cache_struct(self, batch_size: int, max_len: int, img_len: int = 0):
        return jax.eval_shape(lambda: self.init_cache(batch_size, max_len, img_len))
