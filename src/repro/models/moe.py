"""Token-choice MoE with GShard-style capacity dispatch.

Expert placement is the paper's homing decision at pod scale: each expert is
*homed* on a model-axis shard; the dispatch einsum moves each token's
activation to its expert's home (all-to-all), compute runs local to the
expert shard, and the combine einsum brings results back. When the expert
count does not divide the model axis (mixtral: 8 experts, 16-way axis) the
experts are replicated and the FFN dim is TP-sharded instead (see DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import init_mlp, apply_mlp, ninit, pdt
from repro.sharding.partition import MeshPlan, ws


def init_moe(key, cfg: ArchConfig):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    scale_out = 0.02 / max(1.0, (2 * cfg.num_layers) ** 0.5) * 50
    p = {
        "router": ninit(kr, (D, E), jnp.float32),
        "we_gate": ninit(kg, (E, D, F), pdt(cfg)),
        "we_up": ninit(ku, (E, D, F), pdt(cfg)),
        "we_down": ninit(kd, (E, F, D), pdt(cfg), scale_out),
    }
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(ks, cfg, d_ff=cfg.num_shared_experts * F)
    return p


def _capacity(gs: int, cfg: ArchConfig) -> int:
    c = int(-(-gs * cfg.top_k * cfg.capacity_factor // cfg.num_experts))
    return max(4, -(-c // 4) * 4)


def apply_moe(p, x, cfg: ArchConfig, plan: MeshPlan = None, group_size: int = 2048):
    """x: (B, S, D) -> (y, aux_loss). Token-choice top-k with capacity drop."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    T = B * S
    gs = min(group_size, T)
    assert T % gs == 0, f"token count {T} not divisible by group size {gs}"
    Gn = T // gs
    C = _capacity(gs, cfg)
    b_ax = plan.batch_axes if plan else None
    e_ax = plan.expert_axis if plan else None
    f_ax = (plan.tp if plan and e_ax is None else None)
    fs_ax = plan.fsdp_axes if plan else None

    # un-SP first: gathering the bf16 residual here is ~1000x cheaper than
    # letting the dispatch einsum contract over a model-sharded token dim
    # (which psums the full f32 (E,D,Gn,C) dispatch output; mixtral iter2)
    x = ws(x, plan, b_ax, None, None)
    xg = x.reshape(Gn, gs, D)
    xg = ws(xg, plan, b_ax, None, None)
    logits = (xg.astype(jnp.float32) @ p["router"])            # (Gn, gs, E)
    gates = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(gates, K)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)        # renormalise

    # ---- capacity-bucketed dispatch/combine (GShard) ----
    combine = jnp.zeros((Gn, gs, E, C), jnp.float32)
    prev = jnp.zeros((Gn, 1, E), jnp.float32)
    for kk in range(K):
        mk = jax.nn.one_hot(topi[..., kk], E, dtype=jnp.float32)   # (Gn,gs,E)
        posk = jnp.cumsum(mk, axis=1) - mk + prev                  # slot per token
        prev = prev + jnp.sum(mk, axis=1, keepdims=True)
        keep = mk * (posk < C)
        oh = jax.nn.one_hot(posk.astype(jnp.int32), C, dtype=jnp.float32)
        combine = combine + oh * (keep * topv[..., kk:kk + 1])[..., None]
    dispatch = (combine > 0).astype(x.dtype)
    combine = ws(combine, plan, b_ax, None, e_ax, None)

    # ---- send tokens to their experts' home shard (all-to-all under EP) ----
    xe = jnp.einsum("gsec,gsd->gecd", dispatch, xg)
    xe = ws(xe, plan, b_ax, e_ax, None, None)
    g = jnp.einsum("gecd,edf->gecf", xe, p["we_gate"].astype(x.dtype))
    u = jnp.einsum("gecd,edf->gecf", xe, p["we_up"].astype(x.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = ws(h, plan, b_ax, e_ax, None, f_ax)
    ye = jnp.einsum("gecf,efd->gecd", h, p["we_down"].astype(x.dtype))
    ye = ws(ye, plan, b_ax, e_ax, None, None)
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), ye)
    y = y.reshape(B, S, D)

    if "shared" in p:
        y = y + apply_mlp(p["shared"], x, cfg, plan)

    # ---- switch-style load-balance aux ----
    frac_tokens = jnp.mean(jax.nn.one_hot(topi[..., 0], E, dtype=jnp.float32),
                           axis=(0, 1))
    frac_gates = jnp.mean(gates, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_gates)
    return y, aux
