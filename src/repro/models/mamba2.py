"""Mamba2 SSD (state-space duality) block — chunked linear-time scan.

The chunked SSD algorithm is itself the paper's localisation pattern applied
to a recurrence: the sequence is cut into chunks of Q tokens, all heavy
compute (the intra-chunk quadratic part) is *local to a chunk*, and only a
small (H, P, N) state crosses chunk boundaries — exactly "copy your chunk,
work locally, pass on a summary".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import causal_conv1d, gated_rmsnorm, ninit, pdt
from repro.sharding.partition import MeshPlan, ws


def init_mamba(key, cfg: ArchConfig):
    D, di = cfg.d_model, cfg.d_inner
    G, N, Hs, K = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_conv
    kz, kx, kbc, kdt, kcx, kcb, ko = jax.random.split(key, 7)
    return {
        "wz": ninit(kz, (D, di), pdt(cfg)),
        "wx": ninit(kx, (D, di), pdt(cfg)),
        "wBC": ninit(kbc, (D, 2 * G * N), pdt(cfg)),
        "wdt": ninit(kdt, (D, Hs), pdt(cfg)),
        "conv_x": ninit(kcx, (K, di), pdt(cfg), 0.2),
        "conv_bc": ninit(kcb, (K, 2 * G * N), pdt(cfg), 0.2),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, Hs, dtype=jnp.float32)),
        "dt_bias": jnp.full((Hs,), -4.6, jnp.float32),
        "D_skip": jnp.ones((Hs,), jnp.float32),
        "ssm_norm": jnp.ones((di,), jnp.float32),
        "out_proj": ninit(ko, (di, D), pdt(cfg),
                          0.02 / max(1.0, (2 * cfg.num_layers) ** 0.5) * 50),
    }


def _project(p, x, cfg: ArchConfig, conv_state=None):
    """Shared projection + causal conv for both train and decode paths."""
    z = x @ p["wz"].astype(x.dtype)
    xin = x @ p["wx"].astype(x.dtype)
    bc = x @ p["wBC"].astype(x.dtype)
    dtr = x @ p["wdt"].astype(x.dtype)
    cs_x = conv_state["conv_x"] if conv_state else None
    cs_b = conv_state["conv_bc"] if conv_state else None
    xin, ns_x = causal_conv1d(xin, p["conv_x"], cs_x)
    bc, ns_b = causal_conv1d(bc, p["conv_bc"], cs_b)
    xin = jax.nn.silu(xin.astype(jnp.float32)).astype(x.dtype)
    bc = jax.nn.silu(bc.astype(jnp.float32)).astype(x.dtype)
    G, N = cfg.ssm_ngroups, cfg.ssm_state
    B_, C_ = jnp.split(bc, 2, axis=-1)
    B_ = B_.reshape(*B_.shape[:-1], G, N)
    C_ = C_.reshape(*C_.shape[:-1], G, N)
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])
    return z, xin, B_, C_, dt, {"conv_x": ns_x, "conv_bc": ns_b}


def _expand_groups(t, Hs: int):
    """(B, S, G, N) -> (B, S, Hs, N) by broadcasting heads within groups."""
    B, S, G, N = t.shape
    rep = Hs // G
    return jnp.broadcast_to(t[:, :, :, None, :], (B, S, G, rep, N)).reshape(B, S, Hs, N)


def apply_mamba(p, x, cfg: ArchConfig, plan: MeshPlan = None,
                state=None, chunk: int = 256):
    """Train/prefill path. x: (B, S, D) -> (y, final_state_dict)."""
    Bb, S, D = x.shape
    Hs, P, N = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
    h_ax = plan.tp if (plan and plan.mesh is not None and Hs % plan.tp_size == 0) else None
    b_ax = plan.batch_axes if plan else None

    z, xin, B_, C_, dt, conv_state = _project(p, x, cfg)
    Bh = _expand_groups(B_, Hs)
    Ch = _expand_groups(C_, Hs)
    xh = xin.reshape(Bb, S, Hs, P)
    xh = ws(xh, plan, b_ax, None, h_ax, None)
    A = -jnp.exp(p["A_log"])                                   # (Hs,)

    Q = min(chunk, S)
    assert S % Q == 0, f"seq {S} not divisible by ssd chunk {Q}"
    Cn = S // Q
    r = lambda t: t.reshape(Bb, Cn, Q, *t.shape[2:])
    dtc, Bc, Cc, xc = r(dt), r(Bh), r(Ch), r(xh)
    dA = dtc * A                                               # (B,Cn,Q,Hs) f32
    cum = jnp.cumsum(dA, axis=2)

    # ---- intra-chunk (local, quadratic-in-Q) ----
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]        # (B,Cn,Q,Q,Hs)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcihn,bcjhn->bcijh", Cc, Bc,
                        preferred_element_type=jnp.float32)
    W = scores * L * dtc[:, :, None, :, :]                     # (B,Cn,Q,Q,Hs)
    Ydiag = jnp.einsum("bcijh,bcjhp->bcihp", W.astype(x.dtype), xc,
                       preferred_element_type=jnp.float32)

    # ---- chunk summary states (B,Cn,Hs,P,N) ----
    decay_states = jnp.exp(cum[:, :, -1:, :] - cum)            # (B,Cn,Q,Hs)
    wgt = (decay_states * dtc).astype(x.dtype)
    states = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn", Bc, wgt, xc,
                        preferred_element_type=jnp.float32)

    # ---- inter-chunk recurrence (only the small state crosses chunks) ----
    chunk_decay = jnp.exp(cum[:, :, -1, :])                    # (B,Cn,Hs)
    s0 = (state["ssm"].astype(jnp.float32) if state is not None
          else jnp.zeros((Bb, Hs, P, N), jnp.float32))

    def scan_body(s, xs):
        st_c, dec_c = xs
        s_new = s * dec_c[:, :, None, None] + st_c
        return s_new, s                                        # emit state *entering* chunk

    final_state, prev_states = jax.lax.scan(
        scan_body, s0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)              # (B,Cn,Hs,P,N)

    Yoff = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", Cc,
                      prev_states.astype(x.dtype), jnp.exp(cum).astype(x.dtype),
                      preferred_element_type=jnp.float32)
    Y = (Ydiag + Yoff).reshape(Bb, S, Hs, P)
    Y = Y + (p["D_skip"][:, None] * xh.astype(jnp.float32))
    y = Y.astype(x.dtype).reshape(Bb, S, cfg.d_inner)
    y = gated_rmsnorm(y, z, p["ssm_norm"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(x.dtype)
    new_state = {"ssm": final_state,
                 "conv_x": conv_state["conv_x"].astype(x.dtype),
                 "conv_bc": conv_state["conv_bc"].astype(x.dtype)}
    return out, new_state


def decode_mamba(p, x, state, cfg: ArchConfig, plan: MeshPlan = None):
    """Single-token state update. x: (B, 1, D)."""
    Bb = x.shape[0]
    Hs, P, N = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
    z, xin, B_, C_, dt, conv_state = _project(p, x, cfg, conv_state=state)
    Bh = _expand_groups(B_, Hs)[:, 0]                          # (B,Hs,N)
    Ch = _expand_groups(C_, Hs)[:, 0]
    xh = xin.reshape(Bb, Hs, P)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt[:, 0] * A)                                 # (B,Hs)
    s = state["ssm"].astype(jnp.float32)
    upd = jnp.einsum("bhn,bh,bhp->bhpn", Bh.astype(jnp.float32), dt[:, 0],
                     xh.astype(jnp.float32))
    s_new = s * dA[:, :, None, None] + upd
    y = jnp.einsum("bhn,bhpn->bhp", Ch.astype(jnp.float32), s_new)
    y = y + p["D_skip"][:, None] * xh.astype(jnp.float32)
    y = y.astype(x.dtype).reshape(Bb, 1, cfg.d_inner)
    y = gated_rmsnorm(y, z, p["ssm_norm"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(x.dtype)
    new_state = {"ssm": s_new, "conv_x": conv_state["conv_x"],
                 "conv_bc": conv_state["conv_bc"]}
    return out, new_state
