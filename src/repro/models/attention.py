"""Attention: GQA + qk-norm + sliding window + cross-attention + KV cache.

Train/prefill attention is *chunked* (flash-style online softmax over KV
blocks via `lax.scan`) — the VMEM-localisation idea expressed at the XLA
level so that 32k-sequence prefill never materialises an (S x S) score
matrix. The Pallas kernel in `repro.kernels.flash_attention` is the TPU
drop-in for the same computation (`repro.kernels.ops.flash_attention`).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import ninit, pdt, rmsnorm, rope
from repro.sharding.partition import MeshPlan, ws

NEG_INF = -1e30


def init_attention(key, cfg: ArchConfig, cross: bool = False):
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    kq, kk, kv_, ko = jax.random.split(key, 4)
    p = {
        "wq": ninit(kq, (D, H, hd), pdt(cfg)),
        "wk": ninit(kk, (D, KV, hd), pdt(cfg)),
        "wv": ninit(kv_, (D, KV, hd), pdt(cfg)),
        "wo": ninit(ko, (H, hd, D), pdt(cfg), 0.02 / max(1.0, (2 * cfg.num_layers) ** 0.5) * 50),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _head_axes(cfg: ArchConfig, plan: MeshPlan):
    """How to shard the (KV, Gq) grouped-head layout.

    Returns (kv_axis, gq_axis, expand). When the KV-head count does not
    divide the model axis but the full head count does, `expand` asks the
    caller to repeat KV heads up to H at compute time — the repeat is a
    local slice of the (replicated) KV tensor, and it makes the score/PV
    einsums shard over all H heads instead of running fully replicated.
    """
    if plan is None or plan.mesh is None:
        return None, None, False
    gq = cfg.num_heads // max(cfg.num_kv_heads, 1)
    kv_ax = plan.kv_axis
    if kv_ax is not None:
        return kv_ax, None, False
    if gq > 1 and gq % plan.tp_size == 0:
        return None, plan.tp, False
    if cfg.num_heads % plan.tp_size == 0 and gq > 1:
        return plan.tp, None, True  # expanded layout: KV_eff = H, Gq_eff = 1
    return None, None, False


def banded_swa_attention(q, k, v, *, window: int, plan: MeshPlan = None,
                         axes=(None, None)):
    """Sliding-window attention over a 2-block band: O(S*W) instead of O(S^2).

    The paper's locality discipline applied to the sequence dim: query block
    i touches only KV blocks {i-1, i} (block length == window), so 32k-token
    SWA prefill does S/(2W) = 4x less score work and movement than scanning
    every KV chunk (mixtral iter1, EXPERIMENTS.md §Perf).
    q: (B, Sq, KV, Gq, hd); k, v: (B, Sq, KV, hd); Sq % window == 0.
    """
    B, Sq, KV, Gq, hd = q.shape
    W = window
    nq = Sq // W
    kv_ax, gq_ax = axes
    b_ax = plan.batch_axes if plan else None
    scale = hd ** -0.5
    qb = jnp.transpose(q.reshape(B, nq, W, KV, Gq, hd),
                       (0, 1, 3, 4, 2, 5))                 # (B,nq,KV,Gq,W,hd)
    qb = ws(qb, plan, b_ax, None, kv_ax, gq_ax, None, None)
    kb = k.reshape(B, nq, W, KV, hd)
    vb = v.reshape(B, nq, W, KV, hd)
    prev = lambda t: jnp.concatenate(
        [jnp.zeros_like(t[:, :1]), t[:, :-1]], axis=1)
    # relative masks (W, W): diag block = causal & window; prev block = band
    qp = jnp.arange(W)[:, None]
    kp = jnp.arange(W)[None, :]
    mask_diag = (kp <= qp) & (qp - kp < W)
    mask_prev = (qp + W - kp) < W                          # kpos = kp - W
    block_valid = (jnp.arange(nq) > 0)                     # block 0 has no prev

    m = l = acc = None
    for kj, vj, mask, valid in [
            (prev(kb), prev(vb), mask_prev, block_valid),
            (kb, vb, mask_diag, jnp.ones((nq,), bool))]:
        kj = jnp.transpose(kj, (0, 1, 3, 2, 4))            # (B,nq,KV,W,hd)
        vj = jnp.transpose(vj, (0, 1, 3, 2, 4))
        s = jnp.einsum("bnkgqd,bnksd->bnkgqs", qb, kj,
                       preferred_element_type=jnp.float32) * scale
        full_mask = (mask[None, None, None, :, :]
                     & valid[:, None, None, None, None])  # (nq,1,1,W,W)
        s = jnp.where(full_mask[None], s, NEG_INF)
        s = ws(s, plan, b_ax, None, kv_ax, gq_ax, None, None)
        mj = jnp.max(s, axis=-1)
        pj = jnp.exp(s - mj[..., None])
        lj = jnp.sum(pj, axis=-1)
        pvj = jnp.einsum("bnkgqs,bnksd->bnkgqd", pj.astype(vj.dtype), vj,
                         preferred_element_type=jnp.float32)
        if m is None:
            m, l, acc = mj, lj, pvj
        else:
            m_new = jnp.maximum(m, mj)
            c1, c2 = jnp.exp(m - m_new), jnp.exp(mj - m_new)
            l = l * c1 + lj * c2
            acc = acc * c1[..., None] + pvj * c2[..., None]
            m = m_new
    out = acc / jnp.maximum(l, 1e-20)[..., None]           # (B,nq,KV,Gq,W,hd)
    out = jnp.transpose(out, (0, 1, 4, 2, 3, 5)).reshape(B, Sq, KV * Gq, hd)
    return out.astype(q.dtype)


def chunked_attention(q, k, v, *, q_positions, kv_positions, causal: bool,
                      window: int, kv_chunk: int = 1024, plan: MeshPlan = None,
                      axes=(None, None)):
    """Online-softmax attention over KV chunks.

    q: (B, Sq, KV, Gq, hd); k, v: (B, Skv, KV, hd). Positions are int32
    vectors used for causal/sliding-window masking. Returns (B, Sq, KV*Gq, hd).
    """
    B, Sq, KV, Gq, hd = q.shape
    Skv = k.shape[1]
    if (window and causal and Skv == Sq and Sq % window == 0
            and Sq // window > 1):
        return banded_swa_attention(q, k, v, window=window, plan=plan,
                                    axes=axes)
    kv_ax, gq_ax = axes
    b_ax = plan.batch_axes if plan else None
    # fallback when no head dim shards (e.g. musicgen's 24 heads): query rows
    # are independent, so shard the softmax state over the *sequence* dim
    seq_ax = None
    if (kv_ax is None and gq_ax is None and plan is not None
            and plan.mesh is not None and Sq % plan.tp_size == 0 and Sq > 1):
        seq_ax = plan.tp
    scale = hd ** -0.5
    kc = min(kv_chunk, Skv)
    nkc = -(-Skv // kc)
    pad = nkc * kc - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, pad), constant_values=-(10 ** 9))
    # (B, KV, Gq, Sq, hd)
    qt = jnp.transpose(q, (0, 2, 3, 1, 4))
    qt = ws(qt, plan, b_ax, kv_ax, gq_ax, seq_ax, None)
    ks = jnp.transpose(k.reshape(B, nkc, kc, KV, hd), (1, 0, 3, 2, 4))  # (n,B,KV,kc,hd)
    vs = jnp.transpose(v.reshape(B, nkc, kc, KV, hd), (1, 0, 3, 2, 4))
    ks = ws(ks, plan, None, b_ax, kv_ax, None, None)
    vs = ws(vs, plan, None, b_ax, kv_ax, None, None)
    kps = kv_positions.reshape(nkc, kc)

    def body(carry, xs):
        m, l, acc = carry
        kj, vj, kpj = xs
        s = jnp.einsum("bkgqd,bksd->bkgqs", qt, kj,
                       preferred_element_type=jnp.float32) * scale
        s = ws(s, plan, b_ax, kv_ax, gq_ax, seq_ax, None)
        mask = jnp.ones((Sq, kc), bool)
        if causal:
            mask &= kpj[None, :] <= q_positions[:, None]
        if window:
            mask &= (q_positions[:, None] - kpj[None, :]) < window
        mask &= kpj[None, :] >= 0
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqs,bksd->bkgqd", p.astype(vj.dtype), vj,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        # pin the online-softmax state to the head sharding: without this the
        # partitioner is free to re-gather the (B,KV,Gq,Sq,*) state across the
        # model axis on every KV chunk (glm4 iter1, EXPERIMENTS.md §Perf)
        m_new = ws(m_new, plan, b_ax, kv_ax, gq_ax, seq_ax)
        l_new = ws(l_new, plan, b_ax, kv_ax, gq_ax, seq_ax)
        acc_new = ws(acc_new, plan, b_ax, kv_ax, gq_ax, seq_ax, None)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, Gq, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, Gq, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, Gq, Sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (ks, vs, kps))
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    out = jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(B, Sq, KV * Gq, hd)
    return out.astype(q.dtype)


def _rowwise_positions(pos, B: int, Sq: int):
    """Normalise ``pos`` to a (B, Sq) int32 query-position matrix.

    Accepts the legacy scalar (one shared clock), a (B,) per-row clock
    (continuous batching: each slot decodes at its own position), or the
    full (B, Sq) matrix a page-stepped prefill passes."""
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        return jnp.broadcast_to(pos, (B, Sq))
    if pos.ndim == 1:
        return jnp.broadcast_to(pos[:, None], (B, Sq))
    return pos


def decode_attention(q, cache_k, cache_v, kpos, pos, *, window: int,
                     plan: MeshPlan = None, axes=(None, None),
                     cache_seq_axis=None):
    """Attention for Sq new tokens per row over a (ring-buffered) KV cache.

    q: (B, Sq, KV, Gq, hd); cache_k/v: (B, Sc, KV, hd); kpos: (B, Sc) or
    (Sc,) int32 holding the absolute position stored in each cache slot
    (-1 == empty); pos: scalar, (B,) or (B, Sq) int32 query positions.
    Per-row masks keep every row's output a function of its own cache
    alone, so rows at different positions decode in one call.
    """
    B, Sq, KV, Gq, hd = q.shape
    Sc = cache_k.shape[1]
    kpos = jnp.asarray(kpos)
    if kpos.ndim == 1:
        kpos = jnp.broadcast_to(kpos[None], (B, Sc))
    pos = _rowwise_positions(pos, B, Sq)
    kv_ax, gq_ax = axes
    b_ax = plan.batch_axes if plan else None
    scale = hd ** -0.5
    qt = jnp.transpose(q, (0, 2, 3, 1, 4))       # (B, KV, Gq, Sq, hd)
    qt = ws(qt, plan, b_ax, kv_ax, gq_ax, None, None)
    s = jnp.einsum("bkgqd,bskd->bkgqs", qt, cache_k,
                   preferred_element_type=jnp.float32) * scale
    s = ws(s, plan, b_ax, kv_ax, gq_ax, None, cache_seq_axis)
    mask = (kpos[:, None, :] >= 0) & (kpos[:, None, :] <= pos[:, :, None])
    if window:
        mask &= (pos[:, :, None] - kpos[:, None, :]) < window
    s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bkgqs,bskd->bkgqd", (p / l).astype(cache_v.dtype),
                     cache_v, preferred_element_type=jnp.float32)
    out = jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(B, Sq, KV * Gq, hd)
    return out.astype(q.dtype)


def apply_attention(p, x, *, cfg: ArchConfig, plan: MeshPlan,
                    positions=None, cache: Optional[dict] = None,
                    pos=None, kv_src=None, build_cache: bool = False,
                    cross: bool = False, kv_chunk: int = 1024,
                    cache_len: Optional[int] = None, write_mask=None):
    """Full attention block body (no residual/norm — the block adds those).

    Returns (y, new_cache). `cache` (decode) is a dict {k, v, kpos} for self
    attention or {k, v} for cross attention. `build_cache` (prefill) returns
    the cache built from this call's K/V. `write_mask` (B, Sq) bool gates
    which of a decode call's new tokens are committed to the cache (page-
    stepped prefill: pad rows/positions compute but never write).
    """
    B, Sq, D = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    Gq = H // KV
    W = 0 if cross else cfg.sliding_window
    kv_ax, gq_ax, expand = _head_axes(cfg, plan)
    axes = (kv_ax, gq_ax)
    KVe, Gqe = (H, 1) if expand else (KV, Gq)
    rep = (lambda t: jnp.repeat(t, Gq, axis=2)) if expand else (lambda t: t)
    b_ax = plan.batch_axes if plan else None

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    if "q_norm" in p:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)

    new_cache = None
    cs_ax = plan.cache_seq_axis if plan else None
    if cs_ax is not None and cs_ax in (kv_ax, gq_ax):
        # decode memory is cache-read bound: prefer sharding the cache's
        # sequence dim over the model axis; heads stay replicated.
        kv_ax = gq_ax = None
        axes = (None, None)
        KVe, Gqe = KV, Gq
        rep = lambda t: t  # noqa: E731
    if cache is not None and not cross:
        # ---- decode / page-step: Sq new tokens per row, masked ring write
        k_new = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
        v_new = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
        if "k_norm" in p:
            k_new = rmsnorm(k_new, p["k_norm"], cfg.norm_eps)
        pos = _rowwise_positions(pos, B, Sq)
        q = rope(q, pos, cfg.rope_theta)
        k_new = rope(k_new, pos, cfg.rope_theta)
        Sc = cache["k"].shape[1]
        kpos_c = cache["kpos"]
        if kpos_c.ndim == 1:
            kpos_c = jnp.broadcast_to(kpos_c[None], (B, Sc))
        wmask = (jnp.ones((B, Sq), bool) if write_mask is None
                 else write_mask)
        slot = (pos % Sc).astype(jnp.int32)
        # masked one-hot scatter instead of dynamic_update_slice: each row
        # writes its own slot(s), so rows at different positions share one
        # call and a row's cache never depends on its wave-mates (the bit-
        # identity contract).  The one-hot multiply-sum hits exactly one
        # source per written slot (query positions within a call are
        # distinct), so committed values are written exactly.
        oh = ((slot[:, :, None] == jnp.arange(Sc)[None, None, :])
              & wmask[:, :, None])                     # (B, Sq, Sc)
        written = jnp.any(oh, axis=1)                  # (B, Sc)
        upd_k = jnp.einsum("bqs,bqkd->bskd", oh.astype(cache["k"].dtype),
                           k_new.astype(cache["k"].dtype))
        upd_v = jnp.einsum("bqs,bqkd->bskd", oh.astype(cache["v"].dtype),
                           v_new.astype(cache["v"].dtype))
        ck = jnp.where(written[:, :, None, None], upd_k, cache["k"])
        cv = jnp.where(written[:, :, None, None], upd_v, cache["v"])
        kpos = jnp.where(written,
                         jnp.einsum("bqs,bq->bs", oh.astype(jnp.int32), pos),
                         kpos_c)
        new_cache = {"k": ck, "v": cv, "kpos": kpos}
        out = decode_attention(q.reshape(B, Sq, KVe, Gqe, hd), rep(ck), rep(cv),
                               kpos, pos, window=W, plan=plan, axes=axes,
                               cache_seq_axis=plan.cache_seq_axis if plan else None)
    elif cache is not None and cross:
        # ---- decode through a cross layer: static image KV ----
        out = decode_attention(q.reshape(B, 1, KVe, Gqe, hd), rep(cache["k"]),
                               rep(cache["v"]), cache["kpos"], jnp.int32(2 ** 30),
                               window=0, plan=plan, axes=axes)
        new_cache = cache
    else:
        # ---- train / prefill ----
        src = kv_src if cross else x
        k = jnp.einsum("bsd,dhk->bshk", src, p["wk"].astype(x.dtype))
        v = jnp.einsum("bsd,dhk->bshk", src, p["wv"].astype(x.dtype))
        if "k_norm" in p:
            k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
        if positions is None:
            positions = jnp.arange(Sq, dtype=jnp.int32)
        if not cross:
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
            kv_positions = positions
        else:
            kv_positions = jnp.arange(src.shape[1], dtype=jnp.int32)
        out = chunked_attention(q.reshape(B, Sq, KVe, Gqe, hd), rep(k), rep(v),
                                q_positions=positions, kv_positions=kv_positions,
                                causal=not cross, window=W, kv_chunk=kv_chunk,
                                plan=plan, axes=axes)
        if build_cache:
            if cross:
                new_cache = {"k": k, "v": v,
                             "kpos": jnp.zeros((src.shape[1],), jnp.int32)}
            else:
                # cache sized for the full decode horizon; ring-buffer of
                # window size under SWA (requires Sq % window == 0)
                total = max(cache_len or Sq, Sq)
                Sc = min(W, total) if W else total
                keep = min(Sc, Sq)
                ck = k[:, -keep:].astype(x.dtype)
                cv = v[:, -keep:].astype(x.dtype)
                kp = positions[-keep:].astype(jnp.int32)
                if Sc > keep:
                    padw = ((0, 0), (0, Sc - keep), (0, 0), (0, 0))
                    ck = jnp.pad(ck, padw)
                    cv = jnp.pad(cv, padw)
                    kp = jnp.pad(kp, (0, Sc - keep), constant_values=-1)
                # per-row kpos: decode is per-slot-clocked, so each row
                # carries its own occupancy map from here on
                kp = jnp.broadcast_to(kp[None], (B, Sc))
                new_cache = {"k": ck, "v": cv, "kpos": kp}

    out = ws(out, plan, b_ax, None, axes[0] or axes[1], None)
    y = jnp.einsum("bshk,hkd->bsd", out.reshape(*out.shape[:2], H, hd),
                   p["wo"].astype(x.dtype))
    return y, new_cache
