"""Superblock assembly: heterogeneous layer stacks scanned over superblocks.

A *superblock* is the smallest repeating unit of an architecture (1 layer for
dense/MoE/SSM models; 8 layers for Jamba's 1:7 attn:mamba interleave; 5 for
the vision model's 4-self+1-cross pattern). Parameters are stacked with a
leading superblock axis and the stack is `lax.scan`ned — this keeps the HLO
(and compile time) independent of depth and gives remat a natural boundary.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.attention import apply_attention, init_attention
from repro.models.common import init_mlp, apply_mlp, rmsnorm
from repro.models.mamba2 import apply_mamba, decode_mamba, init_mamba
from repro.models.moe import apply_moe, init_moe
from repro.sharding.partition import MeshPlan, ws


@dataclass(frozen=True)
class MemberSpec:
    mixer: str  # "attn" | "mamba" | "cross"
    ffn: str    # "mlp" | "moe" | "none"


def superblock_spec(cfg: ArchConfig) -> List[MemberSpec]:
    if cfg.family == "ssm":
        return [MemberSpec("mamba", "none")]
    if cfg.family == "hybrid":
        out = []
        for i in range(cfg.attn_every):
            mixer = "attn" if i == 0 else "mamba"
            ffn = "moe" if (cfg.is_moe and i % cfg.moe_every == 1) else "mlp"
            out.append(MemberSpec(mixer, ffn))
        return out
    if cfg.family == "vlm":
        n = cfg.cross_attn_every
        return [MemberSpec("attn", "mlp")] * (n - 1) + [MemberSpec("cross", "mlp")]
    ffn = "moe" if cfg.is_moe else "mlp"
    return [MemberSpec("attn", ffn)]


def num_superblocks(cfg: ArchConfig) -> int:
    n = len(superblock_spec(cfg))
    assert cfg.num_layers % n == 0, (cfg.num_layers, n)
    return cfg.num_layers // n


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_member(key, cfg: ArchConfig, spec: MemberSpec):
    k1, k2 = jax.random.split(key)
    D = cfg.d_model
    p = {"ln1": jnp.ones((D,), jnp.float32)}
    if spec.mixer in ("attn", "cross"):
        p["attn"] = init_attention(k1, cfg, cross=(spec.mixer == "cross"))
    else:
        p["mamba"] = init_mamba(k1, cfg)
    if spec.ffn != "none":
        p["ln2"] = jnp.ones((D,), jnp.float32)
        p["moe" if spec.ffn == "moe" else "mlp"] = (
            init_moe(k2, cfg) if spec.ffn == "moe" else init_mlp(k2, cfg))
    return p


def init_stack(key, cfg: ArchConfig):
    members = superblock_spec(cfg)
    nsb = num_superblocks(cfg)
    keys = jax.random.split(key, nsb)

    def init_sb(k):
        ks = jax.random.split(k, len(members))
        return {f"m{i}": _init_member(ks[i], cfg, m)
                for i, m in enumerate(members)}

    return jax.vmap(init_sb)(keys)


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------
def _apply_member(p, spec: MemberSpec, x, *, cfg, plan, positions,
                  img_embeds=None, build_cache: bool, cache_len=None):
    aux = jnp.float32(0.0)
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if spec.mixer == "mamba":
        mix, cache = apply_mamba(p["mamba"], h, cfg, plan)
    else:
        mix, cache = apply_attention(
            p["attn"], h, cfg=cfg, plan=plan, positions=positions,
            kv_src=img_embeds if spec.mixer == "cross" else None,
            cross=(spec.mixer == "cross"), build_cache=build_cache,
            cache_len=cache_len)
    x = x + mix
    if spec.ffn != "none":
        h = rmsnorm(x, p["ln2"], cfg.norm_eps)
        if spec.ffn == "moe":
            f, aux = apply_moe(p["moe"], h, cfg, plan)
        else:
            f = apply_mlp(p["mlp"], h, cfg, plan)
        x = x + f
    return x, cache, aux


def apply_stack(stack, x, *, cfg: ArchConfig, plan: MeshPlan,
                positions=None, img_embeds=None, build_cache: bool = False,
                cache_len=None):
    """x: (B,S,D) -> (y, caches_or_None, aux). Scan over superblocks."""
    members = superblock_spec(cfg)
    b_ax = plan.batch_axes if plan else None
    s_ax = plan.seq_axis if plan else None

    def body(carry, sb_params):
        x = carry
        caches, aux = {}, jnp.float32(0.0)
        for i, m in enumerate(members):
            x, c, a = _apply_member(sb_params[f"m{i}"], m, x, cfg=cfg,
                                    plan=plan, positions=positions,
                                    img_embeds=img_embeds,
                                    build_cache=build_cache,
                                    cache_len=cache_len)
            aux += a
            if build_cache:
                caches[f"m{i}"] = c
        x = ws(x, plan, b_ax, s_ax, None)
        return x, (caches, aux)

    if cfg.parallel.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, (caches, auxs) = jax.lax.scan(body, x, stack)
    return x, (caches if build_cache else None), jnp.sum(auxs)


# ---------------------------------------------------------------------------
# decode (one token, cache/state update)
# ---------------------------------------------------------------------------
def _decode_member(p, spec: MemberSpec, x, cache, pos, *, cfg, plan,
                   write_mask=None):
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if spec.mixer == "mamba":
        if x.shape[1] != 1:
            raise ValueError("mamba decode is strictly sequential: "
                             f"one token per call, got {x.shape[1]}")
        mix, new_cache = decode_mamba(p["mamba"], h, cache, cfg, plan)
    else:
        mix, new_cache = apply_attention(
            p["attn"], h, cfg=cfg, plan=plan, cache=cache, pos=pos,
            cross=(spec.mixer == "cross"), write_mask=write_mask)
    x = x + mix
    if spec.ffn != "none":
        h = rmsnorm(x, p["ln2"], cfg.norm_eps)
        if spec.ffn == "moe":
            f, _ = apply_moe(p["moe"], h, cfg, plan, group_size=x.shape[0])
        else:
            f = apply_mlp(p["mlp"], h, cfg, plan)
        x = x + f
    return x, new_cache


def decode_stack(stack, caches, x, pos, *, cfg: ArchConfig, plan: MeshPlan,
                 write_mask=None):
    """x: (B,S,D); caches: pytree with leading superblock axis.

    ``pos`` is a scalar, (B,) or (B,S) int32 of absolute query positions
    (per-slot clocks); ``write_mask`` (B,S) bool gates cache commits."""
    members = superblock_spec(cfg)

    def body(carry, xs):
        x = carry
        sb_params, sb_cache = xs
        new_caches = {}
        for i, m in enumerate(members):
            x, c = _decode_member(sb_params[f"m{i}"], m, x, sb_cache[f"m{i}"],
                                  pos, cfg=cfg, plan=plan,
                                  write_mask=write_mask)
            new_caches[f"m{i}"] = c
        return x, new_caches

    x, new_caches = jax.lax.scan(body, x, (stack, caches))
    return x, new_caches
