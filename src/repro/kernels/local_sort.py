"""Fused VMEM-resident local phase: leaf sorts + merge tree in ONE kernel.

The engine's reference local phase runs the Pallas leaf sort, then a Python
``while runs.shape[0] > 1`` loop of vmapped searchsorted rank merges — every
tree level materialises the full chunk to HBM and reads it back.  The paper's
Algorithm 2 keeps each worker's `input_cpy` cache-resident for the *entire*
local phase, not just the leaves; this kernel is that discipline for real:

  * one `pallas_call` per chunk: the chunk is copied HBM->VMEM once,
  * the bitonic leaf stages AND all log2(#leaves) merge-tree levels run
    on-chip (the merge levels are the high-`k` stages of the same bitonic
    network — a bitonic merge of two sorted leaves is exactly stage 2*leaf),
  * the fully sorted run is written back once.

HBM traffic: 2*chunk*itemsize total, vs 2*chunk*itemsize*(1 + log2(w)) for
the reference tree — the Fig-1 amortisation argument applied to the sort's
own local phase.

Sentinel padding is folded into the kernel: a non-power-of-two row is
extended to the next power of two with BIG sentinels *in VMEM scratch*
(never materialised to HBM), sorted, and the real prefix written back.
This replaces the engine's old `_leaf_sort` padding, which concatenated a
sentinel tail in HBM on every call (up to 2x wasted traffic for leaf sizes
just above a power of two).

VMEM budget per grid step: next_pow2(chunk) * itemsize for the scratch run
plus the compare-exchange temporaries (~4x that with the partner/min/max
views), e.g. a 64 KiB int32 chunk needs ~0.3 MiB — comfortably inside the
~16 MiB/core budget up to chunks of ~1M elements.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.sort import pad_value
from repro.kernels.bitonic_sort import bitonic_stages


def _kernel(x_ref, o_ref):
    o_ref[...] = bitonic_stages(x_ref[...])


def _kernel_padded(x_ref, o_ref, scratch_ref, *, C: int):
    # the one HBM->VMEM copy; the sentinel tail lives only in scratch
    scratch_ref[...] = jnp.full(scratch_ref.shape,
                                pad_value(x_ref.dtype), x_ref.dtype)
    scratch_ref[:, :C] = x_ref[...]
    o_ref[...] = bitonic_stages(scratch_ref[...])[:, :C]


def local_sort(x, *, interpret: bool = True):
    """Sort each row of x: (rows, C) -> (rows, C), any C >= 1.

    One grid step per row; the whole row (a device chunk: its leaves and the
    full local merge tree) stays in VMEM between the single read and the
    single write-back.  Non-power-of-two C is handled with in-VMEM sentinel
    padding (see module docstring) — callers never pre-pad.
    """
    rows, C = x.shape
    L = 1 << max(0, (C - 1).bit_length())
    if L == C:
        kernel, scratch = _kernel, []
    else:
        kernel = partial(_kernel_padded, C=C)
        scratch = [pltpu.VMEM((1, L), x.dtype)]
    return pl.pallas_call(
        kernel,
        grid=(rows,),
        in_specs=[pl.BlockSpec((1, C), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, C), x.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(x)
