"""Merge-path merge-split kernel: compute only the half you keep.

The engine's block bitonic network exchanges full chunks with a partner and
keeps either the low or the high half of the merged 2C run.  The reference
`_merge_split` merges *everything* (`merge_sorted` -> 2C elements written to
HBM) and then discards half — 2x the merge compute and >2x the HBM traffic
of what the result actually needs.

This kernel partitions the merge by output rank instead (the merge-path /
PCOT "work proportional to what you keep" discipline): the kept half is the
contiguous output window ``k in [0, C)`` (keep-low) or ``k in [C, 2C)``
(keep-high) of the stable rank merge, so it evaluates the gather-form merge
only at those C ranks.  Per row it reads the two C-element runs once, does
O(C log C) rank comparisons (two searchsorted passes — the binary-search
form of the merge-path diagonal), and writes exactly C elements: O(C)
memory, no 2C intermediate, and bit-exact against
``merge_sorted(a, b)[:C]`` / ``[C:]`` including duplicate/sentinel ties
(same ``side="left"`` rank arithmetic as `repro.core.sort.merge_sorted`).

The batched form is the hierarchical engine's cross-pod replay unit: row r
merges pod r's chunk with its partner pod's chunk under its own keep flag.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, keep_ref, o_ref):
    a = a_ref[0, :]
    b = b_ref[0, :]
    C = a.shape[0]
    # output ranks of the kept half: the merge-path window [0,C) or [C,2C)
    k = jnp.arange(C) + jnp.where(keep_ref[0, 0] != 0, 0, C)
    # stable rank merge, gather form, evaluated only at the kept ranks —
    # identical arithmetic to merge_sorted (a-elements win ties, side="left")
    ia = jnp.arange(C) + jnp.searchsorted(b, a, side="left")
    ra = jnp.searchsorted(ia, k, side="left")
    ra_c = jnp.minimum(ra, C - 1)
    is_a = (ra < C) & (jnp.take(ia, ra_c) == k)
    rb = jnp.clip(k - ra, 0, C - 1)
    o_ref[0, :] = jnp.where(is_a, jnp.take(a, ra_c), jnp.take(b, rb))


def merge_split(a, b, keep_low, *, interpret: bool = True):
    """Row-wise merge-split. a, b: (rows, C) sorted rows; keep_low: per-row
    (or scalar, broadcast) flag — True keeps the low half of the merged 2C
    run, False the high half.  Returns (rows, C); bit-exact against
    ``merge_sorted(a[r], b[r])[:C]`` / ``[C:]``.
    """
    rows, C = a.shape
    assert b.shape == (rows, C), (a.shape, b.shape)
    keep = jnp.asarray(keep_low)
    if keep.ndim == 0:
        keep = keep[None]
    if keep.ndim != 1 or keep.shape[0] not in (1, rows):
        raise ValueError(
            f"keep_low must be a scalar or a length-{rows} vector of "
            f"per-row flags (one per merge-split row); got shape "
            f"{jnp.shape(keep_low)} for a/b of shape {(rows, C)}")
    keep = jnp.broadcast_to(keep.astype(jnp.int32)[:, None], (rows, 1))
    return pl.pallas_call(
        _kernel,
        grid=(rows,),
        in_specs=[pl.BlockSpec((1, C), lambda i: (i, 0)),
                  pl.BlockSpec((1, C), lambda i: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, C), a.dtype),
        interpret=interpret,
    )(a, b, keep)
