"""Flash attention Pallas TPU kernel: blocked online softmax with VMEM tiling.

This is KV-localisation at the cache level (DESIGN.md §2): each (query tile,
KV tile) pair is copied HBM->VMEM once via the BlockSpec index maps, all
arithmetic runs on the MXU out of VMEM, and only the finished output tile is
written back. Supports causal masking, sliding windows (with *block
skipping*: fully-masked KV tiles are never computed — the TPU analogue of
not fetching remote lines you will not read) and GQA via the KV index map.

Grid: (B, H, nq, nk) with nk minor-most — TPU grid order makes the KV axis
sequential, so the online-softmax state lives in VMEM scratch across steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int, bq: int, bk: int,
            nk: int, sq: int, skv: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # block skipping: is any element of this tile unmasked?
    first_q, last_q = iq * bq, iq * bq + bq - 1
    first_k, last_k = ik * bk, ik * bk + bk - 1
    live = True
    if causal:
        live = jnp.logical_and(live, first_k <= last_q)
    if window:
        live = jnp.logical_and(live, last_k > first_q - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = (qpos < sq) & (kpos < skv)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ik == nk - 1)
    def _emit():
        l = jnp.maximum(l_scr[...], 1e-20)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    scale: float | None = None, interpret: bool = True):
    """q: (B, H, Sq, hd); k, v: (B, KV, Skv, hd) -> (B, H, Sq, hd)."""
    B, H, Sq, hd = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    G = H // KV
    scale = scale if scale is not None else hd ** -0.5
    bq, bk = min(block_q, Sq), min(block_k, Skv)
    nq, nk = -(-Sq // bq), -(-Skv // bk)
    pq, pk = nq * bq - Sq, nk * bk - Skv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk, nk=nk,
                               sq=Sq, skv=Skv)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * bq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq]
