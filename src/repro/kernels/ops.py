"""Jitted public wrappers for the Pallas kernels.

`interpret=True` (default on CPU) runs the kernel bodies in Python for
correctness validation; on TPU pass interpret=False to lower for real.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import bitonic_sort as _bs
from repro.kernels import flash_attention as _fa
from repro.kernels import local_sort as _ls
from repro.kernels import localised_copy as _lc
from repro.kernels import merge_split as _ms
from repro.core.sort import merge_sorted


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128, interpret=True):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def bitonic_sort(x, *, interpret=True):
    return _bs.bitonic_sort(x, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def chunked_sort(x, *, interpret=True):
    """Full 1-D sort: Pallas local sort per chunk + rank-merge tree."""
    runs = bitonic_sort(x, interpret=interpret)
    while runs.shape[0] > 1:
        runs = jax.vmap(merge_sorted)(runs[0::2], runs[1::2])
    return runs[0]


@partial(jax.jit, static_argnames=("interpret",))
def local_sort(x, *, interpret=True):
    """Fused local phase: leaf sorts + the whole merge tree, one VMEM pass."""
    return _ls.local_sort(x, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def merge_split(a, b, keep_low, *, interpret=True):
    """Merge-path merge-split: only the kept half is computed/written."""
    return _ms.merge_split(a, b, keep_low, interpret=interpret)


@partial(jax.jit, static_argnames=("reps", "interpret"))
def localised_copy(x, reps: int, *, interpret=True):
    return _lc.localised_copy(x, reps, interpret=interpret)
