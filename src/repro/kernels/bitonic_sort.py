"""In-VMEM bitonic sort Pallas kernel — the local phase of distributed sort.

One grid step sorts one chunk entirely in VMEM: the chunk is copied
HBM->VMEM once (the paper's `input_cpy` memcpy, Algorithm 2), all
O(L log^2 L) compare-exchange stages run on-chip, and the sorted run is
written back once. Partner exchange is expressed with reshape+flip (no
gathers), which maps onto TPU vector shuffles.

`bitonic_stages` is the network itself, shared with the fused
`local_sort` kernel (leaf sorts + the whole local merge tree in one
pallas_call — see `repro.kernels.local_sort`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def bitonic_stages(v):
    """Sort each row of v: (R, L) ascending. L must be a power of two.

    The classic network: stage k sorts every aligned k-block, alternating
    direction by the block's position bit so stage 2k sees bitonic input.
    Rows are contiguous in the row-major reshape, so the same
    reshape+flip partner exchange sorts all R rows at once.
    """
    R, L = v.shape
    assert L & (L - 1) == 0, f"bitonic length {L} not a power of 2"
    if L == 1:
        return v
    idx = jax.lax.broadcasted_iota(jnp.int32, (R, L), 1)
    k = 2
    while k <= L:
        j = k // 2
        while j >= 1:
            r = v.reshape(-1, 2, j)
            partner = jnp.flip(r, axis=1).reshape(R, L)
            asc = (idx & k) == 0 if k < L else jnp.ones((R, L), bool)
            lower = (idx & j) == 0
            mn = jnp.minimum(v, partner)
            mx = jnp.maximum(v, partner)
            v = jnp.where(lower == asc, mn, mx)
            j //= 2
        k *= 2
    return v


def _kernel(x_ref, o_ref):
    o_ref[...] = bitonic_stages(x_ref[...])


def bitonic_sort(x, *, interpret: bool = True):
    """Row-wise sort. x: (chunks, L), L a power of two; one chunk per grid step."""
    chunks, L = x.shape
    return pl.pallas_call(
        _kernel,
        grid=(chunks,),
        in_specs=[pl.BlockSpec((1, L), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, L), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((chunks, L), x.dtype),
        interpret=interpret,
    )(x)
