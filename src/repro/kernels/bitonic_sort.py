"""In-VMEM bitonic sort Pallas kernel — the local phase of distributed sort.

One grid step sorts one chunk entirely in VMEM: the chunk is copied
HBM->VMEM once (the paper's `input_cpy` memcpy, Algorithm 2), all
O(L log^2 L) compare-exchange stages run on-chip, and the sorted run is
written back once. Partner exchange is expressed with reshape+flip (no
gathers), which maps onto TPU vector shuffles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bitonic_stages(v):
    """Sort each row of v: (1, L) ascending. L must be a power of two."""
    L = v.shape[-1]
    assert L & (L - 1) == 0, f"bitonic length {L} not a power of 2"
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, L), 1)
    k = 2
    while k <= L:
        j = k // 2
        while j >= 1:
            r = v.reshape(-1, 2, j)
            partner = jnp.flip(r, axis=1).reshape(1, L)
            asc = (idx & k) == 0 if k < L else jnp.ones((1, L), bool)
            lower = (idx & j) == 0
            mn = jnp.minimum(v, partner)
            mx = jnp.maximum(v, partner)
            v = jnp.where(lower == asc, mn, mx)
            j //= 2
        k *= 2
    return v


def _kernel(x_ref, o_ref):
    o_ref[...] = _bitonic_stages(x_ref[...])


def bitonic_sort(x, *, interpret: bool = True):
    """Row-wise sort. x: (chunks, L), L a power of two; one chunk per grid step."""
    chunks, L = x.shape
    return pl.pallas_call(
        _kernel,
        grid=(chunks,),
        in_specs=[pl.BlockSpec((1, L), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, L), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((chunks, L), x.dtype),
        interpret=interpret,
    )(x)
