"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  scale: float | None = None):
    """Dense softmax attention. q: (B,H,Sq,hd); k,v: (B,KV,Skv,hd)."""
    B, H, Sq, hd = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    G = H // KV
    scale = scale if scale is not None else hd ** -0.5
    kr = jnp.repeat(k, G, axis=1)
    vr = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32)).astype(q.dtype)


def sort_ref(x):
    """Row-wise sort oracle. x: (rows, L)."""
    return jnp.sort(x, axis=-1)


def localised_copy_ref(x, reps: int):
    """The non-localised execution order: full-array pass per repetition
    (each pass re-streams the whole array through HBM). x: (chunks, block)."""
    y = x.astype(jnp.float32)
    for _ in range(reps):
        y = y * 1.0001 + 1.0
    return y.astype(x.dtype)
