# Pallas kernels for the VMEM-resident local phase.

# Per-core VMEM capacity the kernels budget against (the ~16 MiB scratch
# space of a TPU core; CPU interpret mode has no hard ceiling but the
# production contract is sized to this).  `local_sort` documents the chunk
# bound this implies; `repro.analysis` rule R3 enforces it statically for
# every `pallas_call` in a lowered workload.
VMEM_BYTES_PER_CORE = 16 * 1024 * 1024
