# Pallas kernels for the VMEM-resident local phase.

# Per-core VMEM capacity the kernels budget against (the ~16 MiB scratch
# space of a TPU core; CPU interpret mode has no hard ceiling but the
# production contract is sized to this).  `local_sort` documents the chunk
# bound this implies; `repro.analysis` rule R3 enforces it statically for
# every `pallas_call` in a lowered workload.
VMEM_BYTES_PER_CORE = 16 * 1024 * 1024

# Per-device HBM capacity the compiled programs budget against (a 16 GiB
# accelerator attach point; CPU emulation has host RAM instead but the
# production contract is sized to this).  `repro.analysis` rule R10 gates
# each workload's peak live bytes — from the XLA buffer liveness of the
# compiled module — against it, and the headroom it reports is what sizes
# the KV prefix pools of the serving scheduler.
HBM_BYTES_PER_DEVICE = 16 * 1024 * 1024 * 1024
