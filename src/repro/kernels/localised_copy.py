"""The paper's Fig-1 micro-benchmark as a TPU kernel.

Localised version: the chunk is copied HBM->VMEM once (BlockSpec), then all
R repetition passes run *inside* VMEM before one write-back — arithmetic
intensity scales with R. The non-localised reference (`ref.localised_copy_ref`
compiled as written) performs R full-array passes, re-streaming HBM every
pass. Identical arithmetic, different locality — the Fig-1 gap.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref, *, reps: int):
    y = x_ref[...].astype(jnp.float32)

    def body(_, y):
        return y * 1.0001 + 1.0

    y = jax.lax.fori_loop(0, reps, body, y)
    o_ref[...] = y.astype(o_ref.dtype)


def localised_copy(x, reps: int, *, block: int | None = None,
                   interpret: bool = True):
    """x: (chunks, block_len) -> same shape; R passes per chunk in VMEM."""
    chunks, L = x.shape
    bl = block or L
    return pl.pallas_call(
        functools.partial(_kernel, reps=reps),
        grid=(chunks,),
        in_specs=[pl.BlockSpec((1, bl), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, bl), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((chunks, L), x.dtype),
        interpret=interpret,
    )(x)
