"""Per-home paged KV pool with refcounts, LRU free-list and radix prefix
reuse — the serving analogue of the paper's localised chunks.

A slot's KV cache used to be opaque whole-slot state: an affinity hit
saved *relayout bytes* but re-computed the prefix it already held.  This
module splits the prompt side of a slot's cache into fixed-size **pages**
(``page_size`` tokens each) and gives every home its own pool of them:

* pages are **content-addressed** — a page's key is the full token prefix
  it closes (a hash chain over prompt blocks), so two requests sharing a
  prompt prefix share page keys, and the longest-prefix lookup over a
  request's block chain *is* the radix match;
* each pooled page carries a **refcount** (in-flight requests pin it) and
  a ``last_used`` stamp; unreferenced pages form the LRU free-list an
  over-capacity insert evicts from;
* a prefix hit on the request's **own home** attaches the pooled pages
  and skips their prefill compute entirely; attaching never crosses
  homes — a session whose cache lives elsewhere pays the existing
  fork-vs-migrate relayout charge first (scheduler I1), which is the
  paper's fork-free-within-home / charged-across-homes rule extended to
  prefix blocks;
* sharing is **copy-on-write by construction**: attached page *content*
  is copied into the row's private cache region, and the first token a
  row deviates by changes every later page key, so forked continuations
  never alias (COW at page granularity without aliasing machinery).

The pure accounting (``acquire``/``release``/``invalidate`` over a tuple
of `Page`) lives in the scheduler's `SchedState` so `schedcheck` R9 can
certify it exhaustively (invariant I8: refcounts never leak, attach never
crosses homes, capacity is never exceeded).  The device-side content —
per-layer K/V blocks — lives host-side in a `PageStore` owned by the
server, pruned against the pool state after every completion.

Bit-identity note: a page's K/V content is a pure function of the tokens
up to its end (page p attends only to positions < its own — see
`LM.decode_pages`), so an attached page is byte-for-byte the page the row
would have computed; fifo and homed serve identical tokens no matter how
their hit patterns differ.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, NamedTuple, Optional, Sequence, Tuple


class Page(NamedTuple):
    """One pooled KV page: its content key, pin count and LRU stamp."""
    key: object
    refs: int
    last_used: float


PoolPages = Tuple[Page, ...]


def prompt_blocks(prompt: Sequence[int], page_size: int) -> Tuple[object, ...]:
    """The cacheable block-key chain of a prompt.

    Block i covers token positions [i*page_size, (i+1)*page_size); only
    *full* pages strictly before the page holding the last prompt token
    are cacheable (the last page is still being written when the first
    output token is sampled).  Keys are the full token prefix each block
    closes — the hash-chain/radix property: equal key => equal tokens so
    far => equal K/V content.
    """
    toks = tuple(int(t) for t in prompt)
    if page_size <= 0 or len(toks) == 0:
        return ()
    n = (len(toks) - 1) // page_size
    return tuple(toks[:(i + 1) * page_size] for i in range(n))


def lookup(pages: PoolPages, blocks: Sequence[object]) -> int:
    """Radix longest-prefix match: how many leading blocks the pool holds."""
    keys = {p.key for p in pages}
    hit = 0
    while hit < len(blocks) and blocks[hit] in keys:
        hit += 1
    return hit


def acquire(pages: PoolPages, blocks: Sequence[object], capacity: int,
            now: float, known: Optional[frozenset] = None
            ) -> Tuple[PoolPages, int]:
    """Pin a request's block chain into one home's pool.

    Returns ``(pages', attached)``.  ``attached`` is the longest-prefix
    hit against ``known`` — the key set at *wave start* (default: the
    pool's current keys): only those pages have content ready to attach;
    a block committed by a wave-mate moments ago is refcounted as shared
    but still computed by this row.  Every block gets refs+1 (present)
    or is inserted with refs=1, evicting the LRU *unreferenced* page when
    the pool is full; a pool pinned full stops inserting (those blocks
    simply stay uncached — correctness never depends on an insert).
    """
    out: List[Page] = list(pages)
    if known is None:
        known = frozenset(p.key for p in out)
    hit = 0
    while hit < len(blocks) and blocks[hit] in known:
        hit += 1
    for b in blocks:
        idx = next((i for i, p in enumerate(out) if p.key == b), None)
        if idx is not None:
            out[idx] = out[idx]._replace(refs=out[idx].refs + 1,
                                         last_used=now)
            continue
        if len(out) >= capacity:
            free = [i for i, p in enumerate(out) if p.refs == 0]
            if not free:
                break                      # pinned full: rest stay uncached
            out.pop(min(free, key=lambda i: (out[i].last_used, i)))
        out.append(Page(b, 1, now))
    return tuple(out), hit


def release(pages: PoolPages, blocks: Sequence[object],
            now: float) -> PoolPages:
    """Unpin a completed request's blocks: refs-1 on each present page.

    Tolerates absent keys (the page was force-invalidated mid-flight —
    the fleet-reliability path: the request finished on its private cache
    copy and simply has nothing left to unpin) and never drives a
    refcount negative.
    """
    out = list(pages)
    for b in blocks:
        for i, p in enumerate(out):
            if p.key == b:
                if p.refs > 0:
                    out[i] = p._replace(refs=p.refs - 1, last_used=now)
                break
    return tuple(out)


def invalidate(pages: PoolPages,
               keys: Optional[Iterable[object]] = None) -> PoolPages:
    """Force-drop pages (all of them when ``keys`` is None) regardless of
    refcounts — device loss / evacuation.  In-flight requests keep their
    private cache copies and their later `release` is tolerated; the next
    request of the session re-enters as a fresh, charged prefill."""
    if keys is None:
        return ()
    drop = set(keys)
    return tuple(p for p in pages if p.key not in drop)


class PageStore:
    """Host-side page content, keyed (home, block-key) — the server's half
    of the pool.  The pure pool state decides *which* keys exist; this
    store holds their per-layer K/V arrays and is pruned to the pool's
    key set after every scheduler transition, so eviction/invalidate in
    the accounting layer frees the bytes here."""

    def __init__(self, tracer=None):
        from repro.obs import NULL_TRACER     # local: keep module zero-dep
        self._data: Dict[int, Dict[object, object]] = {}
        self.tracer = tracer if tracer is not None else NULL_TRACER

    def put(self, home: int, key: object, content) -> None:
        if self.tracer.enabled and not self.has(home, key):
            self.tracer.count("store.pages", 1, cat="pool", home=home)
        self._data.setdefault(home, {})[key] = content

    def get(self, home: int, key: object):
        return self._data.get(home, {}).get(key)

    def has(self, home: int, key: object) -> bool:
        return key in self._data.get(home, {})

    def prune(self, home: int, live_keys: Iterable[object]) -> int:
        """Drop content for keys the pool no longer holds; returns count."""
        live = set(live_keys)
        tbl = self._data.get(home, {})
        dead = [k for k in tbl if k not in live]
        for k in dead:
            del tbl[k]
        if dead and self.tracer.enabled:
            self.tracer.count("store.pages", -len(dead), cat="pool",
                              home=home)
        return len(dead)

    def clear(self) -> None:
        self._data.clear()
