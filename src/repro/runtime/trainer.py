"""Fault-tolerant training loop.

Failure model (designed for 1000+ nodes, exercised here in-process):
  * crash/preemption  — atomic checkpoints every K steps; SIGTERM/SIGINT
    trigger a final save; restart resumes from the latest complete step and,
    because the data pipeline is a pure function of the step index, replays
    the exact same batches (bitwise-deterministic resume, tested).
  * bad steps         — non-finite loss or exploding grad-norm aborts the
    step, restores the last checkpoint in-process and skips the offending
    batch (loss-spike guard).
  * stragglers        — per-step wall-time watchdog: steps slower than
    `straggler_factor` x running median are logged and counted; the
    Supervisor (ft.py) escalates to a restart after `max_slow_steps`
    (on a real pod: re-scheduling the slow host).
"""
from __future__ import annotations

import json
import os
import signal
import statistics
import time
from dataclasses import dataclass, field
from functools import partial
from typing import Optional

import numpy as np

import jax

from repro.checkpoint import CheckpointManager, latest_step, restore
from repro.configs.base import ArchConfig
from repro.data import make_batch_iterator
from repro.models.model import LM
from repro.models.steps import init_opt_state, make_train_step
from repro.optim import AdamW, cosine_schedule
from repro.sharding.partition import MeshPlan, NULL_PLAN


@dataclass
class TrainerConfig:
    steps: int = 100
    global_batch: int = 8
    seq_len: int = 64
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 20
    log_every: int = 10
    seed: int = 0
    lr: float = 3e-4
    warmup: int = 10
    straggler_factor: float = 3.0
    schedule_total: Optional[int] = None   # decouple LR horizon from loop end
    grad_spike: float = 1e4
    metrics_path: Optional[str] = None


class Trainer:
    def __init__(self, cfg: ArchConfig, tcfg: TrainerConfig,
                 plan: MeshPlan = NULL_PLAN, mesh=None):
        self.cfg, self.tcfg, self.plan, self.mesh = cfg, tcfg, plan, mesh
        self.model = LM(cfg)
        total = tcfg.schedule_total or tcfg.steps
        self.opt = AdamW(lr=cosine_schedule(tcfg.lr, tcfg.warmup, total))
        self.step_fn = jax.jit(make_train_step(self.model, cfg, plan, self.opt),
                               donate_argnums=(0, 1))
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, tcfg.ckpt_every)
        self.slow_steps = 0
        self._stop = False

    # ------------------------------------------------------------- lifecycle
    def _install_signal_handlers(self):
        def handler(signum, frame):
            self._stop = True
        for sig in (signal.SIGTERM, signal.SIGINT):
            signal.signal(sig, handler)

    def init_or_restore(self):
        params = self.model.init(jax.random.key(self.tcfg.seed))
        opt_state = init_opt_state(self.cfg, self.opt, params)
        start = 0
        last = latest_step(self.tcfg.ckpt_dir)
        if last is not None:
            state = restore(self.tcfg.ckpt_dir, last,
                            {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start = last
        return params, opt_state, start

    # ------------------------------------------------------------------ run
    def run(self) -> dict:
        self._install_signal_handlers()
        t = self.tcfg
        params, opt_state, start = self.init_or_restore()
        data = make_batch_iterator(self.cfg, t.global_batch, t.seq_len,
                                   t.seed, self.mesh, start_step=start)
        durations, metrics_log = [], []
        step = start
        for step in range(start, t.steps):
            if self._stop:
                break
            batch = next(data)
            t0 = time.time()
            new_params, new_opt, m = self.step_fn(params, opt_state, batch)
            loss = float(m["loss"])
            gnorm = float(m["grad_norm"])
            dt = time.time() - t0
            # ---- loss-spike / NaN guard ----
            if not np.isfinite(loss) or gnorm > t.grad_spike:
                last = latest_step(t.ckpt_dir)
                if last is not None:
                    st = restore(t.ckpt_dir, last,
                                 {"params": params, "opt": opt_state})
                    params, opt_state = st["params"], st["opt"]
                continue  # skip the offending batch, keep going
            params, opt_state = new_params, new_opt
            # ---- straggler watchdog ----
            durations.append(dt)
            med = statistics.median(durations[-50:])
            if len(durations) > 5 and dt > t.straggler_factor * med:
                self.slow_steps += 1
            if (step + 1) % t.log_every == 0 or step + 1 == t.steps:
                rec = {"step": step + 1, "loss": loss, "grad_norm": gnorm,
                       "step_time_s": round(dt, 4),
                       "slow_steps": self.slow_steps}
                metrics_log.append(rec)
                print(json.dumps(rec), flush=True)
            self.ckpt.maybe_save(step + 1,
                                 {"params": params, "opt": opt_state})
        self.ckpt.maybe_save(step + 1, {"params": params, "opt": opt_state},
                             force=True)
        self.ckpt.wait()
        if t.metrics_path:
            with open(t.metrics_path, "w") as f:
                json.dump(metrics_log, f, indent=1)
        return {"final_step": step + 1,
                "final_loss": metrics_log[-1]["loss"] if metrics_log else None,
                "params": params}
