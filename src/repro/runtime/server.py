"""Batched decode server: fixed-slot continuous batching over decode_step.

Requests queue up; whenever slots free (EOS/max-len), queued prompts are
prefilled into the freed slots at the next wave boundary. All active slots
share the decode position clock (aligned batching); per-slot masks retire
finished sequences. The KV cache is donated across steps (free-asap).

Cache placement goes through the same `Locale` API as every other workload:
each request's KV-cache slot is homed chunk-contiguously over the batch-slot
axis (`Locale.pin_tree` inside the jitted step), so a slot's cache lives
wholly on the device that decodes it instead of being re-laid-out by the
compiler per decode step — the paper's one-shot localisation applied to
serving state.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.api import Locale
from repro.models.model import LM
from repro.sharding.partition import MeshPlan, NULL_PLAN


@dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (P,) int32
    max_new: int = 16
    out: List[int] = field(default_factory=list)
    done: bool = False


class DecodeServer:
    def __init__(self, cfg: ArchConfig, params, batch_slots: int = 4,
                 max_len: int = 128, plan: MeshPlan = NULL_PLAN,
                 greedy: bool = True, locale: Optional[Locale] = None):
        assert cfg.embed_input, "server serves token LMs"
        self.cfg, self.params, self.plan = cfg, params, plan
        self.B, self.max_len = batch_slots, max_len
        self.model = LM(cfg)
        self.queue: List[Request] = []
        self.greedy = greedy
        if locale is None:
            # home cache slots over the plan's batch axes; degenerate
            # (no-op) locale when the plan has no mesh or no batch sharding.
            # batch_axes is a *tuple* of mesh axis names (("data",) or
            # ("pod", "data")) — pass it through as the locale's (possibly
            # multi-) axis, never coerced to a single axis string.
            slot_axes = (tuple(plan.batch_axes or ())
                         if plan.mesh is not None else ())
            locale = (Locale(mesh=plan.mesh, axis=slot_axes)
                      if slot_axes else Locale(mesh=None))
        self.locale = locale

        def _step(p, c, b, pos):
            logits, c2 = self.model.decode_step(p, c, b, pos, plan)
            # re-home each slot's cache on its decode device (slot dim = 1:
            # cache leaves are (layers, slot, ...); non-slot leaves skipped)
            c2 = self.locale.pin_tree(c2, dim=1, size=b["tokens"].shape[0])
            return logits, c2

        self._decode = self.locale.jit(_step, donate=(1,))

    def submit(self, req: Request):
        self.queue.append(req)

    def _wave(self, reqs: List[Request]) -> List[Request]:
        """Serve one aligned wave: common-length prefill + decode to done."""
        B = len(reqs)
        plen = max(1, max(len(r.prompt) for r in reqs))
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(reqs):  # left-pad with token 0
            toks[i, plen - len(r.prompt):] = r.prompt
        last, caches = self.model.prefill(
            self.params, {"tokens": jnp.asarray(toks)}, self.plan,
            max_len=self.max_len)
        pos = plen
        cur = np.asarray(jnp.argmax(last, -1)) if self.greedy else None
        for i, r in enumerate(reqs):
            r.out.append(int(cur[i]))
        max_new = max(r.max_new for r in reqs)
        for _ in range(max_new - 1):
            batch = {"tokens": jnp.asarray(cur[:, None].astype(np.int32))}
            logits, caches = self._decode(self.params, caches, batch,
                                          jnp.int32(pos))
            cur = np.asarray(jnp.argmax(logits, -1))
            pos += 1
            for i, r in enumerate(reqs):
                if len(r.out) < r.max_new and not r.done:
                    r.out.append(int(cur[i]))
            if pos >= self.max_len:
                break
        for r in reqs:
            r.done = True
        return reqs

    def run(self) -> List[Request]:
        """Drain the queue in slot-sized waves (continuous re-batching)."""
        served = []
        while self.queue:
            wave, self.queue = self.queue[:self.B], self.queue[self.B:]
            served += self._wave(wave)
        return served
