"""Batched decode server: continuous batching over per-slot position clocks.

Requests queue up; whenever slots free (EOS/max-len), queued prompts are
prefilled into the freed slots. The KV cache is donated across steps
(free-asap).

Cache placement goes through the same `Locale` API as every other workload:
each request's KV-cache slot is homed chunk-contiguously over the batch-slot
axis (`Locale.pin_tree` inside the jitted step), so a slot's cache lives
wholly on the device that decodes it instead of being re-laid-out by the
compiler per decode step — the paper's one-shot localisation applied to
serving state.

*Which* request lands on which slot is the scheduler's decision
(`repro.runtime.scheduler`): ``scheduler="fifo"`` is the arrival-order
oracle (a freed slot takes the head of one global queue),
``scheduler="homed"`` routes/batches/evicts by the slot ownership map
`Locale.owners` so a request only ever decodes on its assigned home.

Two serving modes, picked at construction:

**paged / continuous** (``prompt_pad`` set, attention-only stack, no
sliding window) — prompts are *right*-padded into a fixed bucket, so row
r's tokens sit at positions ``[0, plen)`` no matter which requests share
the batch, and every row decodes at its own position clock (a ``(B,)``
vector): a freed slot refills mid-wave while its neighbours keep
decoding.  Prefill runs page-stepped (``page_size`` tokens per jitted
call), which is what lets a prompt's leading pages be *attached* from the
home's paged KV pool (`repro.runtime.kvpool`) instead of recomputed when
a home-resident prefix matches — the radix prefix-reuse path.  Each
row's numerics are a pure function of its own prompt (the fixed bucket
keeps them composition-independent), so decode outputs are bit-identical
across scheduling policies for the same request set, whatever their
prefix-hit patterns.

**aligned waves** (everything else) — the legacy mode: a wave of slots is
prefilled together (left-padded to ``prompt_pad`` or the wave max) and
all active slots share one decode position clock; per-slot masks retire
finished sequences.  Architectures with sequential state (SSM/hybrid
members, sliding windows) serve here.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.api import Locale
from repro.models.blocks import superblock_spec
from repro.models.model import LM
from repro.runtime.kvpool import PageStore
from repro.runtime.scheduler import Scheduler, make_scheduler
from repro.sharding.partition import MeshPlan, NULL_PLAN


@dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (P,) int32
    max_new: int = 16
    out: List[int] = field(default_factory=list)
    done: bool = False
    session: Optional[object] = None  # affinity key (prefix/session KV reuse)
    t_arrive: float = 0.0        # open-loop arrival, in wave-step units
    home: Optional[int] = None   # assigned home device (set at admission)
    wait: Optional[float] = None # admission wait in wave-step units


class DecodeServer:
    def __init__(self, cfg: ArchConfig, params, batch_slots: int = 4,
                 max_len: int = 128, plan: MeshPlan = NULL_PLAN,
                 greedy: bool = True, locale: Optional[Locale] = None,
                 scheduler: Union[str, Scheduler] = "fifo",
                 prompt_pad: Optional[int] = None,
                 page_size: Optional[int] = None,
                 page_capacity: Optional[int] = None,
                 tracer=None):
        assert cfg.embed_input, "server serves token LMs"
        self.cfg, self.params, self.plan = cfg, params, plan
        self.B, self.max_len = batch_slots, max_len
        self.model = LM(cfg)
        self.greedy = greedy
        self.prompt_pad = prompt_pad
        if locale is None:
            # home cache slots over the plan's batch axes; degenerate
            # (no-op) locale when the plan has no mesh or no batch sharding.
            # batch_axes is a *tuple* of mesh axis names (("data",) or
            # ("pod", "data")) — pass it through as the locale's (possibly
            # multi-) axis, never coerced to a single axis string.
            slot_axes = (tuple(plan.batch_axes or ())
                         if plan.mesh is not None else ())
            locale = (Locale(mesh=plan.mesh, axis=slot_axes)
                      if slot_axes else Locale(mesh=None))
        self.locale = locale

        # the paged/continuous mode needs position-pure rows: a fixed
        # right-pad bucket, no sequential member state (mamba), and no
        # ring-wrapped window (a page's cache slot must be its position)
        self.paged = (prompt_pad is not None
                      and all(m.mixer == "attn" for m in superblock_spec(cfg))
                      and not cfg.sliding_window)
        if isinstance(scheduler, str):
            ps = page_size if page_size is not None else \
                (min(4, prompt_pad) if self.paged else 0)
            if self.paged and page_capacity is None:
                # per home: enough pages for session_capacity resident
                # prefix chains — the same 4x-slots-per-home sizing as the
                # binding table, and comfortably inside the R10 decode
                # HBM headroom (pages are prompt-prefix KV already priced
                # by kv_bytes_per_token)
                owners = locale.owners(self.B)
                sph = max(owners.count(h) for h in set(owners))
                page_capacity = 4 * sph * max(1, (prompt_pad - 1) // ps)
            scheduler = make_scheduler(
                scheduler, n_slots=self.B, locale=self.locale, cfg=cfg,
                prompt_pad=prompt_pad, page_size=ps if self.paged else 0,
                page_capacity=page_capacity if self.paged else 0,
                tracer=tracer)
        if scheduler.n_slots != self.B:
            raise ValueError(f"scheduler manages {scheduler.n_slots} slots, "
                             f"server has {self.B}")
        if scheduler.page_capacity > 0 and not self.paged:
            raise ValueError(
                "a paged KV pool needs prompt_pad and an attention-only, "
                "non-sliding-window stack")
        self.scheduler = scheduler
        # the server traces through the scheduler's tracer — one stream,
        # one sink; a NullTracer keeps every instrumented path free
        self.tracer = scheduler.tracer
        self.page_size = (scheduler.page_size if scheduler.page_size
                          else (min(4, prompt_pad) if self.paged else 0))
        # host-side page content, keyed by home; shares the trace stream
        self.store = PageStore(tracer=self.tracer)

        def _step(p, c, b, pos):
            logits, c2 = self.model.decode_step(p, c, b, pos, plan)
            # re-home each slot's cache on its decode device (slot dim = 1:
            # cache leaves are (layers, slot, ...); non-slot leaves skipped)
            c2 = self.locale.pin_tree(c2, dim=1, size=b["tokens"].shape[0])
            return logits, c2

        self._decode = self.locale.jit(_step, donate=(1,))
        if self.paged:
            self._build_paged_steps()

    # ------------------------------------------------------------ paged jits
    def _build_paged_steps(self):
        ps, plan = self.page_size, self.plan

        def _page(p, c, toks, positions, wmask):
            logits, c2 = self.model.decode_pages(
                p, c, {"tokens": toks}, positions, wmask, plan)
            c2 = self.locale.pin_tree(c2, dim=1, size=toks.shape[0])
            return logits, c2

        def _reset(c, mask):
            # wipe refilled rows' attention timelines: kpos (nsb, B, Sc)
            # back to -1 so stale entries from the slot's previous tenant
            # never pass the kpos>=0 mask
            def f(leaf):
                if leaf.ndim == 3 and leaf.dtype == jnp.int32:
                    return jnp.where(mask[None, :, None], jnp.int32(-1),
                                     leaf)
                return leaf
            return jax.tree.map(f, c)

        def _attach(c, kb, vb, mask, p0):
            # splice one pooled page level into the refilled rows' caches:
            # rows in ``mask`` take kb/vb content (and positions
            # p0..p0+ps) at cache slots [p0, p0+ps) — everyone else keeps
            # their own cache untouched
            out = {}
            pos_vals = p0 + jnp.arange(ps, dtype=jnp.int32)
            for m, sub in c.items():
                k, v, kp = sub["k"], sub["v"], sub["kpos"]
                curk = jax.lax.dynamic_slice_in_dim(k, p0, ps, axis=2)
                newk = jnp.where(mask[None, :, None, None, None], kb[m],
                                 curk)
                k = jax.lax.dynamic_update_slice_in_dim(k, newk, p0, axis=2)
                curv = jax.lax.dynamic_slice_in_dim(v, p0, ps, axis=2)
                newv = jnp.where(mask[None, :, None, None, None], vb[m],
                                 curv)
                v = jax.lax.dynamic_update_slice_in_dim(v, newv, p0, axis=2)
                curp = jax.lax.dynamic_slice_in_dim(kp, p0, ps, axis=2)
                newp = jnp.where(mask[None, :, None], pos_vals[None, None],
                                 curp)
                kp = jax.lax.dynamic_update_slice_in_dim(kp, newp, p0,
                                                         axis=2)
                out[m] = {"k": k, "v": v, "kpos": kp}
            return self.locale.pin_tree(out, dim=1, size=mask.shape[0])

        self._page = self.locale.jit(_page, donate=(1,))
        self._reset = self.locale.jit(_reset, donate=(0,))
        self._attach = self.locale.jit(_attach, donate=(0,))

    # ------------------------------------------------------------ submission
    def submit(self, req: Request):
        if self.prompt_pad is not None and len(req.prompt) > self.prompt_pad:
            raise ValueError(
                f"request {req.rid}: prompt length {len(req.prompt)} exceeds "
                f"prompt_pad={self.prompt_pad}")
        self.scheduler.submit(req)

    # ------------------------------------------------- legacy aligned waves
    def _serve_wave(self, placements) -> Tuple[List[Request], float]:
        """Serve one aligned wave of slot-placed requests.

        The batch always carries all B slot rows (empty slots hold a dummy
        token row — one compiled shape for every wave); a row's computation
        never depends on the other rows, so each request's tokens are a
        function of its own prompt alone.  Returns the served requests and
        the wave's cost in step units (prefill rows + forward steps).
        """
        B = self.B
        reqs: List[Optional[Request]] = [None] * B
        for slot, r in placements:
            reqs[slot] = r
        active = [r for r in reqs if r is not None]
        plen = (self.prompt_pad if self.prompt_pad is not None
                else max(1, max(len(r.prompt) for r in active)))
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(reqs):             # left-pad with token 0
            if r is not None:
                toks[i, plen - len(r.prompt):] = r.prompt
        last, caches = self.model.prefill(
            self.params, {"tokens": jnp.asarray(toks)}, self.plan,
            max_len=self.max_len)
        pos = plen
        cur = np.asarray(jnp.argmax(last, -1)) if self.greedy else None
        for i, r in enumerate(reqs):
            if r is not None:
                r.out.append(int(cur[i]))
        steps = 1
        max_new = max(r.max_new for r in active)
        for _ in range(max_new - 1):
            batch = {"tokens": jnp.asarray(cur[:, None].astype(np.int32))}
            logits, caches = self._decode(self.params, caches, batch,
                                          jnp.int32(pos))
            cur = np.asarray(jnp.argmax(logits, -1))
            pos += 1
            steps += 1
            for i, r in enumerate(reqs):
                if r is None:
                    continue
                if len(r.out) < r.max_new and not r.done:
                    r.out.append(int(cur[i]))
            if pos >= self.max_len:
                break
        for r in active:
            r.done = True
        return active, float(plen + steps)

    def _run_waves(self) -> List[Request]:
        served: List[Request] = []
        sch = self.scheduler
        now = 0.0
        while sch.has_work():
            now = sch.clock(now)
            wave = sch.form_wave(now)
            if not wave:          # future arrivals only — jump, then retry
                continue
            with self.tracer.span("serve.wave", cat="serve", now=now,
                                  placed=len(wave)) as sp:
                reqs, cost = self._serve_wave(wave)
                sp.set(cost=cost)
            sch.complete(wave, now, cost)
            now += cost
            served += reqs
        return served

    # --------------------------------------------------- paged / continuous
    def _refill(self, wave, slots, caches, pos_np, cur_np, now):
        """Prefill freshly placed requests into their (freed) slots while
        the other rows' caches ride along untouched.

        Per row: reset its attention timeline, *attach* the leading
        prompt pages its home's pool already holds (splice pooled KV —
        no compute), then run the remaining pages through the page-
        stepped prefill (`LM.decode_pages`), committing KV only for this
        wave's rows at their own positions.  Newly pooled blocks have
        their computed content extracted into the host `PageStore` so
        later waves can attach them.  Returns (caches, cost_units).
        """
        sch, ps, B = self.scheduler, self.page_size, self.B
        rows = [slot for slot, _ in wave]
        for slot, r in wave:
            slots[slot] = r
        rmask = np.zeros((B,), bool)
        rmask[rows] = True
        caches = self._reset(caches, jnp.asarray(rmask))

        plen = {s: len(r.prompt) for s, r in wave}
        blocks = {s: getattr(r, "_sched_blocks", ()) for s, r in wave}
        # attachable = the scheduler's pre-wave longest-prefix hit, capped
        # by what the content store actually holds (a mid-flight
        # invalidation may have raced the accounting — recompute instead
        # of trusting a stale attach)
        att = {}
        for s, r in wave:
            n = 0
            while (n < getattr(r, "_attached", 0)
                   and self.store.has(r.home, blocks[s][n])):
                n += 1
            att[s] = n
        if self.tracer.enabled:
            aps = sum(att.values())
            self.tracer.event(
                "serve.attach", cat="serve", now=now, pages=aps,
                per_slot={s: att[s] for s, _ in wave},
                rows_saved=round(aps * ps / self.prompt_pad, 2))

        # 1. attach pooled page levels (no compute, no cost)
        max_att = max(att.values(), default=0)
        dtype = jnp.dtype(self.cfg.dtype)
        members = [f"m{i}" for i in range(len(superblock_spec(self.cfg)))]
        struct = self.model.cache_struct(B, self.max_len)
        for p in range(max_att):
            lv = [s for s, _ in wave if att[s] > p]
            if not lv:
                continue
            kb = {m: np.zeros(
                (struct[m]["k"].shape[0], B, ps) + struct[m]["k"].shape[3:],
                dtype) for m in members}
            vb = {m: np.zeros_like(kb[m]) for m in members}
            amask = np.zeros((B,), bool)
            for s in lv:
                content = self.store.get(slots[s].home, blocks[s][p])
                amask[s] = True
                for m in members:
                    kb[m][:, s], vb[m][:, s] = content[m]
            caches = self._attach(
                caches, {m: jnp.asarray(kb[m]) for m in members},
                {m: jnp.asarray(vb[m]) for m in members},
                jnp.asarray(amask), jnp.int32(p * ps))

        # 2. page-stepped prefill for everything not attached
        cost = 0.0
        lastlv = {s: (plen[s] - 1) // ps for s, _ in wave}
        level_logits: Dict[int, np.ndarray] = {}
        for p in range(max(lastlv.values()) + 1):
            lv = [s for s, _ in wave if att[s] <= p <= lastlv[s]]
            if not lv:
                continue
            toks = np.zeros((B, ps), np.int32)
            wm = np.zeros((B, ps), bool)
            for s in lv:
                chunk = slots[s].prompt[p * ps:(p + 1) * ps]
                toks[s, :len(chunk)] = chunk
                wm[s, :len(chunk)] = True
            positions = np.broadcast_to(
                p * ps + np.arange(ps, dtype=np.int32), (B, ps))
            logits, caches = self._page(
                self.params, caches, jnp.asarray(toks),
                jnp.asarray(positions), jnp.asarray(wm))
            cost += float(ps)
            if any(lastlv[s] == p for s in lv):
                level_logits[p] = np.asarray(logits)

        # 3. first sampled token + per-slot clock start
        for s, r in wave:
            off = (plen[s] - 1) - lastlv[s] * ps
            first = int(np.argmax(level_logits[lastlv[s]][s, off]))
            r.out.append(first)
            cur_np[s] = first
            pos_np[s] = plen[s]

        # 4. publish newly pooled blocks' computed content to the store
        live = {h: set(sch.pool_keys(h)) for h in sch.homes}
        for s, r in wave:
            for i in range(att[s], len(blocks[s])):
                key = blocks[s][i]
                if key not in live.get(r.home, ()) \
                        or self.store.has(r.home, key):
                    continue
                content = {}
                for m in members:
                    content[m] = (
                        np.asarray(caches[m]["k"][:, s, i * ps:(i + 1) * ps]),
                        np.asarray(caches[m]["v"][:, s, i * ps:(i + 1) * ps]))
                self.store.put(r.home, key, content)
        return caches, cost

    def _run_paged(self) -> List[Request]:
        """Continuous batching: per-slot position clocks, mid-wave refill.

        One loop iteration = (refill any freed slots) + (one decode step
        for every occupied slot).  Inactive rows carry a dummy token at a
        stale clock — their writes are row-local and wiped at refill, so
        no active row ever observes them.
        """
        served: List[Request] = []
        sch, B = self.scheduler, self.B
        slots: List[Optional[Request]] = [None] * B
        caches = None
        pos_np = np.zeros((B,), np.int32)
        cur_np = np.zeros((B,), np.int32)
        now = 0.0
        while sch.has_work() or any(r is not None for r in slots):
            free = [i for i, r in enumerate(slots) if r is None]
            occupied = any(r is not None for r in slots)
            if free and sch.has_work():
                if not occupied:
                    now = sch.clock(now)     # idle: jump to next arrival
                wave = sch.form_wave(now, free_slots=free)
                if wave:
                    if caches is None:
                        caches = self.locale.pin_tree(
                            self.model.init_cache(B, self.max_len),
                            dim=1, size=B)
                    with self.tracer.span("serve.refill", cat="serve",
                                          now=now, placed=len(wave)) as sp:
                        caches, cost = self._refill(wave, slots, caches,
                                                    pos_np, cur_np, now)
                        sp.set(cost=cost)
                    sch.tick(cost)
                    now += cost
                elif not occupied:
                    continue                 # future arrivals only — retry
            if not any(r is not None for r in slots):
                continue
            batch = {"tokens": jnp.asarray(cur_np[:, None])}
            if self.tracer.enabled:
                act = [s for s, r in enumerate(slots) if r is not None]
                dspan = self.tracer.span(
                    "serve.decode", cat="serve", now=now, active=len(act),
                    pos_min=int(pos_np[act].min()),
                    pos_max=int(pos_np[act].max()))
            else:
                dspan = self.tracer.span("serve.decode")
            with dspan:
                logits, caches = self._decode(self.params, caches, batch,
                                              jnp.asarray(pos_np))
            cur_np = np.asarray(jnp.argmax(logits, -1)).astype(np.int32)
            pos_np = pos_np + 1
            sch.tick(1.0)
            now += 1.0
            done_now = []
            for s, r in enumerate(slots):
                if r is None:
                    continue
                if len(r.out) < r.max_new:
                    r.out.append(int(cur_np[s]))
                if len(r.out) >= r.max_new or int(pos_np[s]) >= self.max_len:
                    r.done = True
                    done_now.append((s, r))
                    slots[s] = None
            if done_now:
                sch.complete(done_now, now)
                served += [r for _, r in done_now]
                for h in sch.homes:          # eviction frees host bytes too
                    self.store.prune(h, sch.pool_keys(h))
        return served

    def run(self) -> List[Request]:
        """Drain the queues (continuous batching when the config supports
        it, aligned waves otherwise).

        The scheduler decides wave membership and slot placement; the
        simulated clock advances by each step's cost, so open-loop
        arrivals (``Request.t_arrive``) and admission waits are measured in
        the same deterministic units across policies.
        """
        if self.paged:
            return self._run_paged()
        return self._run_waves()
