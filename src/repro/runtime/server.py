"""Batched decode server: fixed-slot continuous batching over decode_step.

Requests queue up; whenever slots free (EOS/max-len), queued prompts are
prefilled into the freed slots at the next wave boundary. All active slots
share the decode position clock (aligned batching); per-slot masks retire
finished sequences. The KV cache is donated across steps (free-asap).

Cache placement goes through the same `Locale` API as every other workload:
each request's KV-cache slot is homed chunk-contiguously over the batch-slot
axis (`Locale.pin_tree` inside the jitted step), so a slot's cache lives
wholly on the device that decodes it instead of being re-laid-out by the
compiler per decode step — the paper's one-shot localisation applied to
serving state.

*Which* request lands on which slot is the scheduler's decision
(`repro.runtime.scheduler`): ``scheduler="fifo"`` is the arrival-order
oracle (today's behaviour — a wave is the first B queued requests),
``scheduler="homed"`` routes/batches/evicts by the slot ownership map
`Locale.owners` so a request only ever decodes on its assigned home.

``prompt_pad`` fixes the prefill left-pad length for every wave (instead
of the per-wave max).  With a fixed pad, each batch row's tokens occupy
the same positions regardless of which other requests share the wave, and
rows never mix in the model — so decode outputs are bit-identical across
scheduling policies for the same request set (the fifo-vs-homed oracle
check), at the cost of prefilling the pad bucket.  ``prompt_pad=None``
keeps the per-wave-max behaviour.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple, Union

import numpy as np

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.api import Locale
from repro.models.model import LM
from repro.runtime.scheduler import Scheduler, make_scheduler
from repro.sharding.partition import MeshPlan, NULL_PLAN


@dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (P,) int32
    max_new: int = 16
    out: List[int] = field(default_factory=list)
    done: bool = False
    session: Optional[object] = None  # affinity key (prefix/session KV reuse)
    t_arrive: float = 0.0        # open-loop arrival, in wave-step units
    home: Optional[int] = None   # assigned home device (set at admission)
    wait: Optional[float] = None # admission wait in wave-step units


class DecodeServer:
    def __init__(self, cfg: ArchConfig, params, batch_slots: int = 4,
                 max_len: int = 128, plan: MeshPlan = NULL_PLAN,
                 greedy: bool = True, locale: Optional[Locale] = None,
                 scheduler: Union[str, Scheduler] = "fifo",
                 prompt_pad: Optional[int] = None):
        assert cfg.embed_input, "server serves token LMs"
        self.cfg, self.params, self.plan = cfg, params, plan
        self.B, self.max_len = batch_slots, max_len
        self.model = LM(cfg)
        self.greedy = greedy
        self.prompt_pad = prompt_pad
        if locale is None:
            # home cache slots over the plan's batch axes; degenerate
            # (no-op) locale when the plan has no mesh or no batch sharding.
            # batch_axes is a *tuple* of mesh axis names (("data",) or
            # ("pod", "data")) — pass it through as the locale's (possibly
            # multi-) axis, never coerced to a single axis string.
            slot_axes = (tuple(plan.batch_axes or ())
                         if plan.mesh is not None else ())
            locale = (Locale(mesh=plan.mesh, axis=slot_axes)
                      if slot_axes else Locale(mesh=None))
        self.locale = locale
        if isinstance(scheduler, str):
            scheduler = make_scheduler(scheduler, n_slots=self.B,
                                       locale=self.locale, cfg=cfg,
                                       prompt_pad=prompt_pad)
        if scheduler.n_slots != self.B:
            raise ValueError(f"scheduler manages {scheduler.n_slots} slots, "
                             f"server has {self.B}")
        self.scheduler = scheduler

        def _step(p, c, b, pos):
            logits, c2 = self.model.decode_step(p, c, b, pos, plan)
            # re-home each slot's cache on its decode device (slot dim = 1:
            # cache leaves are (layers, slot, ...); non-slot leaves skipped)
            c2 = self.locale.pin_tree(c2, dim=1, size=b["tokens"].shape[0])
            return logits, c2

        self._decode = self.locale.jit(_step, donate=(1,))

    def submit(self, req: Request):
        if self.prompt_pad is not None and len(req.prompt) > self.prompt_pad:
            raise ValueError(
                f"request {req.rid}: prompt length {len(req.prompt)} exceeds "
                f"prompt_pad={self.prompt_pad}")
        self.scheduler.submit(req)

    def _serve_wave(self, placements) -> Tuple[List[Request], float]:
        """Serve one aligned wave of slot-placed requests.

        The batch always carries all B slot rows (empty slots hold a dummy
        token row — one compiled shape for every wave); a row's computation
        never depends on the other rows, so each request's tokens are a
        function of its own prompt alone.  Returns the served requests and
        the wave's cost in step units (prefill rows + forward steps).
        """
        B = self.B
        reqs: List[Optional[Request]] = [None] * B
        for slot, r in placements:
            reqs[slot] = r
        active = [r for r in reqs if r is not None]
        plen = (self.prompt_pad if self.prompt_pad is not None
                else max(1, max(len(r.prompt) for r in active)))
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(reqs):             # left-pad with token 0
            if r is not None:
                toks[i, plen - len(r.prompt):] = r.prompt
        last, caches = self.model.prefill(
            self.params, {"tokens": jnp.asarray(toks)}, self.plan,
            max_len=self.max_len)
        pos = plen
        cur = np.asarray(jnp.argmax(last, -1)) if self.greedy else None
        for i, r in enumerate(reqs):
            if r is not None:
                r.out.append(int(cur[i]))
        steps = 1
        max_new = max(r.max_new for r in active)
        for _ in range(max_new - 1):
            batch = {"tokens": jnp.asarray(cur[:, None].astype(np.int32))}
            logits, caches = self._decode(self.params, caches, batch,
                                          jnp.int32(pos))
            cur = np.asarray(jnp.argmax(logits, -1))
            pos += 1
            steps += 1
            for i, r in enumerate(reqs):
                if r is None:
                    continue
                if len(r.out) < r.max_new and not r.done:
                    r.out.append(int(cur[i]))
            if pos >= self.max_len:
                break
        for r in active:
            r.done = True
        return active, float(plen + steps)

    def run(self) -> List[Request]:
        """Drain the queues in slot-sized waves (continuous re-batching).

        The scheduler decides wave membership and slot placement; the
        simulated clock advances by each wave's step cost, so open-loop
        arrivals (``Request.t_arrive``) and admission waits are measured in
        the same deterministic units across policies.
        """
        served: List[Request] = []
        sch = self.scheduler
        now = 0.0
        while sch.has_work():
            now = sch.clock(now)
            wave = sch.form_wave(now)
            if not wave:          # future arrivals only — jump, then retry
                continue
            reqs, cost = self._serve_wave(wave)
            sch.complete(wave, now, cost)
            now += cost
            served += reqs
        return served
