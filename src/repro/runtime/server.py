"""Batched decode server: fixed-slot continuous batching over decode_step.

Requests queue up; whenever slots free (EOS/max-len), queued prompts are
prefilled into the freed slots at the next wave boundary. All active slots
share the decode position clock (aligned batching); per-slot masks retire
finished sequences. The KV cache is donated across steps (free-asap).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.model import LM
from repro.sharding.partition import MeshPlan, NULL_PLAN


@dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (P,) int32
    max_new: int = 16
    out: List[int] = field(default_factory=list)
    done: bool = False


class DecodeServer:
    def __init__(self, cfg: ArchConfig, params, batch_slots: int = 4,
                 max_len: int = 128, plan: MeshPlan = NULL_PLAN,
                 greedy: bool = True):
        assert cfg.embed_input, "server serves token LMs"
        self.cfg, self.params, self.plan = cfg, params, plan
        self.B, self.max_len = batch_slots, max_len
        self.model = LM(cfg)
        self.queue: List[Request] = []
        self.greedy = greedy
        self._decode = jax.jit(
            lambda p, c, b, pos: self.model.decode_step(p, c, b, pos, plan),
            donate_argnums=(1,))

    def submit(self, req: Request):
        self.queue.append(req)

    def _wave(self, reqs: List[Request]) -> List[Request]:
        """Serve one aligned wave: common-length prefill + decode to done."""
        B = len(reqs)
        plen = max(1, max(len(r.prompt) for r in reqs))
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(reqs):  # left-pad with token 0
            toks[i, plen - len(r.prompt):] = r.prompt
        last, caches = self.model.prefill(
            self.params, {"tokens": jnp.asarray(toks)}, self.plan,
            max_len=self.max_len)
        pos = plen
        cur = np.asarray(jnp.argmax(last, -1)) if self.greedy else None
        for i, r in enumerate(reqs):
            r.out.append(int(cur[i]))
        max_new = max(r.max_new for r in reqs)
        for _ in range(max_new - 1):
            batch = {"tokens": jnp.asarray(cur[:, None].astype(np.int32))}
            logits, caches = self._decode(self.params, caches, batch,
                                          jnp.int32(pos))
            cur = np.asarray(jnp.argmax(logits, -1))
            pos += 1
            for i, r in enumerate(reqs):
                if len(r.out) < r.max_new and not r.done:
                    r.out.append(int(cur[i]))
            if pos >= self.max_len:
                break
        for r in reqs:
            r.done = True
        return reqs

    def run(self) -> List[Request]:
        """Drain the queue in slot-sized waves (continuous re-batching)."""
        served = []
        while self.queue:
            wave, self.queue = self.queue[:self.B], self.queue[self.B:]
            served += self._wave(wave)
        return served
