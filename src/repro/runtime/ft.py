"""Process-level fault tolerance: supervisor with heartbeat + relaunch.

On a real cluster each host runs its shard of the pjit program under a
supervisor like this one; a dead/hung/straggling worker is killed and the
job relaunches from the latest atomic checkpoint. Here the supervised unit
is a training subprocess, which lets the restart/resume path be tested for
real (see tests/test_runtime.py): kill -9 mid-run, relaunch, verify the
loss curve continues from the checkpoint as if uninterrupted.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time
from dataclasses import dataclass
from typing import List, Optional

from repro.obs import NULL_TRACER


def evacuate_home(scheduler, home: Optional[int] = None,
                  store=None) -> dict:
    """Serving-side device-loss/drain hook: drop one home's pooled prompt
    pages (every home when ``home`` is None) and the host-side content
    backing them.

    This is the paged-KV analogue of the kill -9 -> relaunch path below:
    the pool state is *accounting*, not truth — in-flight requests hold
    private copies of everything they attached, so they finish untouched
    and their completion-time release finds nothing to unpin (tolerated
    by `kvpool.release`, never a refcount crash).  What the evacuation
    does change is the future: the affected sessions' next requests find
    no pooled prefix and re-enter as fresh, *charged* prefills — the cost
    of the loss is paid visibly, in the same relayout ledger as every
    other cross-home byte.
    """
    dropped = scheduler.invalidate_pages(home)
    pruned = 0
    if store is not None:
        homes = scheduler.homes if home is None else [home]
        for h in homes:
            pruned += store.prune(h, scheduler.pool_keys(h))
    rec = {"home": home, "pages_dropped": dropped,
           "content_pruned": pruned}
    getattr(scheduler, "tracer", NULL_TRACER).event(
        "ft.evacuate", cat="ft", **rec)
    return rec


@dataclass
class Supervisor:
    cmd: List[str]
    max_restarts: int = 3
    heartbeat_timeout_s: float = 300.0   # no stdout for this long == hung
    env: Optional[dict] = None
    tracer: object = None                # repro.obs tracer; None == off

    def run(self) -> dict:
        """Supervise to completion; always returns a structured record.

        ``{"ok", "reason", "restarts", "hangs", "final_rc", "history",
        "stdout"}`` where ``reason`` is one of:

          completed            — a clean exit within the restart budget
          max_restarts         — the relaunch budget ran out
          hung_restart_budget  — the final attempt exited 0, but only
                                 after `max_restarts` heartbeat-kill
                                 restarts: a worker that repeatedly hung
                                 and then limped to rc=0 is NOT a healthy
                                 run, and used to be reported as success.

        Crashes (non-zero exits without a heartbeat kill) consume the
        restart budget but never poison a subsequent clean exit — the
        kill -9 -> relaunch -> resume path is the designed recovery.
        """
        tr = self.tracer if self.tracer is not None else NULL_TRACER
        restarts = 0
        hangs = 0
        history = []
        while True:
            t0 = time.time()
            last_beat = time.time()
            proc = subprocess.Popen(
                self.cmd, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
                text=True, env={**os.environ, **(self.env or {})})
            lines = []
            hung = False
            while True:
                line = proc.stdout.readline()
                if line:
                    last_beat = time.time()
                    lines.append(line.rstrip())
                elif proc.poll() is not None:
                    break
                if time.time() - last_beat > self.heartbeat_timeout_s:
                    hung = True
                    proc.kill()          # hung / straggling worker
                    break
            rc = proc.wait()
            hangs += int(hung)
            attempt = {"rc": rc, "hung": hung,
                       "seconds": round(time.time() - t0, 1),
                       "lines": len(lines)}
            history.append(attempt)
            tr.event("ft.attempt", cat="ft", attempt=len(history),
                     **attempt)

            def result(ok: bool, reason: str) -> dict:
                rec = {"ok": ok, "reason": reason, "restarts": restarts,
                       "hangs": hangs, "final_rc": rc,
                       "history": history, "stdout": lines}
                tr.event("ft.result", cat="ft", ok=ok, reason=reason,
                         restarts=restarts, hangs=hangs, final_rc=rc,
                         attempts=len(history))
                return rec

            if rc == 0 and not hung:
                if hangs >= self.max_restarts:
                    return result(False, "hung_restart_budget")
                return result(True, "completed")
            restarts += 1
            if restarts > self.max_restarts:
                return result(False, "max_restarts")
            # relaunch: trainer resumes from the latest atomic checkpoint
