"""Process-level fault tolerance: supervisor with heartbeat + relaunch.

On a real cluster each host runs its shard of the pjit program under a
supervisor like this one; a dead/hung/straggling worker is killed and the
job relaunches from the latest atomic checkpoint. Here the supervised unit
is a training subprocess, which lets the restart/resume path be tested for
real (see tests/test_runtime.py): kill -9 mid-run, relaunch, verify the
loss curve continues from the checkpoint as if uninterrupted.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time
from dataclasses import dataclass
from typing import List, Optional


@dataclass
class Supervisor:
    cmd: List[str]
    max_restarts: int = 3
    heartbeat_timeout_s: float = 300.0   # no stdout for this long == hung
    env: Optional[dict] = None

    def run(self) -> dict:
        restarts = 0
        history = []
        while True:
            t0 = time.time()
            last_beat = time.time()
            proc = subprocess.Popen(
                self.cmd, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
                text=True, env={**os.environ, **(self.env or {})})
            lines = []
            while True:
                line = proc.stdout.readline()
                if line:
                    last_beat = time.time()
                    lines.append(line.rstrip())
                elif proc.poll() is not None:
                    break
                if time.time() - last_beat > self.heartbeat_timeout_s:
                    proc.kill()          # hung / straggling worker
                    break
            rc = proc.wait()
            history.append({"rc": rc, "seconds": round(time.time() - t0, 1),
                            "lines": len(lines)})
            if rc == 0:
                return {"ok": True, "restarts": restarts,
                        "history": history, "stdout": lines}
            restarts += 1
            if restarts > self.max_restarts:
                return {"ok": False, "restarts": restarts,
                        "history": history, "stdout": lines}
            # relaunch: trainer resumes from the latest atomic checkpoint
