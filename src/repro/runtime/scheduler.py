"""Home-aware serving scheduler: admission, batching, eviction by cache home.

PR 2 homed each decode slot's KV cache on the device that computes it
(`Locale.pin_tree` over the batch-slot axis).  That localises serving
*state*; this module localises serving *decisions*.  The paper's ownership
math (`chunk_bounds`: worker w owns one contiguous chunk) is applied to
decode slots instead of sort chunks: slot s of a B-slot server on an
N-device locale is *homed* on device `Locale.owners(B)[s]`, and every
scheduling decision — which home admits a request, which requests form the
next wave, which cached session is evicted — is made in home terms.

Two policies, selected by ``Scheduler(policy=...)`` (and surfaced as
``DecodeServer(scheduler=...)`` / ``repro.launch.serve --policy``):

``"fifo"``
    The oracle: today's behaviour.  One global queue; a wave is the first B
    queued requests; a request lands on whatever slot frees first, so a
    recurring session's cached KV prefix is dragged to an arbitrary home
    almost every time it returns (cross-home relayout), and a burst of
    long decodes padlocks every slot behind the longest request.

``"homed"``
    The paper's discipline:

    * **admission** — per-home queues.  A request is routed at arrival to
      the home its session's KV already lives on (affinity), else to the
      least-loaded home; it never decodes anywhere but its assigned home.
    * **batch formation** — at each wave boundary the scheduler picks the
      step *target* that maximises slot utilisation over the visible
      queue windows (so short decodes batch with short decodes instead of
      padlocking behind a long one), then every home fills its own slots
      from its own queue, front first, with requests fitting the target.
      A request skipped ``max_skip`` waves forces the target up to its own
      span — aging bounds staleness.
    * **spill** — work conservation: a home with free slots and an empty
      (or drained) queue pulls fitting work from other homes' queues,
      cheapest relayout first (unbound sessions move free; same-pod donors
      break ties so a spill crosses DCN only when ICI has nothing to
      give), and the bytes it does move are charged — measured, not
      hidden.  A spilled session with work still queued at its bound home
      takes a one-way *copy* (the canonical cache stays put); it migrates
      only when nothing remains for it at home.
    * **eviction/compaction** — per-home LRU over session bindings.  A
      binding is only ever *dropped* on its own home, never migrated to
      another home's table: a live cache never moves off its home.

Relayout accounting is analytic, like `engine.exchange_schedule`: moving a
session with T cached tokens across homes costs ``T * kv_bytes_per_token``
bytes, split inter-pod vs intra-pod on hierarchical (pod-major) locales.
Both policies run bit-identical decode compute for the same request set
(the server's fixed ``prompt_pad`` makes each row's numerics independent
of wave composition), so the byte/step deltas are pure scheduling wins.
"""
from __future__ import annotations

import heapq
import math
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

POLICIES = ("fifo", "homed")


def kv_bytes_per_token(cfg) -> int:
    """Analytic KV-cache bytes one decoded token pins to a slot's home.

    The attention K+V rows per *attention* layer (`cfg.attn_layers` — the
    full stack for pure-attention families, the sparse subset for hybrids,
    empty for pure-SSM): the dominant, relayout-priced state.  SSM members
    carry O(1)-per-sequence state and are ignored, like small replicated
    leaves in `Locale.pin_tree`.
    """
    itemsize = np.dtype(cfg.dtype).itemsize
    return len(cfg.attn_layers) * 2 * cfg.num_kv_heads * cfg.head_dim \
        * itemsize


@dataclass
class _Binding:
    """Where a session's cached KV prefix lives: its *home* and size."""
    home: int
    tokens: int
    last_used: float


@dataclass
class _Entry:
    req: object
    skips: int = 0


@dataclass
class HomeStats:
    admitted: int = 0
    spilled_in: int = 0
    spilled_out: int = 0
    evicted: int = 0
    relayout_bytes: int = 0      # bytes charged for sessions moved ONTO this home


@dataclass
class ScheduleStats:
    """Deterministic per-run accounting (wall clock lives in the bench)."""
    homes: Dict[int, HomeStats] = field(default_factory=dict)
    waves: int = 0
    steps: float = 0.0           # wave cost units: prefill rows + decode steps
    slot_steps: float = 0.0      # n_slots * steps (capacity offered)
    busy_slot_steps: float = 0.0 # sum over served reqs of their own span
    waits: List[float] = field(default_factory=list)
    relayout_bytes: int = 0      # total cross-home session-cache movement
    inter_pod_bytes: int = 0     # subset crossing a pod boundary
    intra_pod_bytes: int = 0
    relayout_events: int = 0
    served: int = 0
    tokens_out: int = 0

    def wait_pct(self, q: float) -> float:
        if not self.waits:
            return 0.0
        return float(np.percentile(np.asarray(self.waits), q))


class Scheduler:
    """Route, batch and evict decode requests by KV-cache home.

    ``owners`` maps slot index -> home-device index (``Locale.owners``:
    `chunk_bounds` applied to slots).  ``homes_per_pod`` is the number of
    homes per pod on a hierarchical (pod-major) locale — it only affects
    the inter/intra-pod split of the relayout bytes and the spill donor
    preference; ``None`` means a flat (single-distance-class) locale.
    """

    def __init__(self, n_slots: int, owners: Optional[Sequence[int]] = None,
                 policy: str = "fifo", bytes_per_token: int = 0,
                 lookahead: int = 8, max_skip: int = 4,
                 homes_per_pod: Optional[int] = None,
                 session_capacity: Optional[int] = None,
                 affinity_slack: Optional[int] = None,
                 prompt_pad: Optional[int] = None):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; want one of "
                             f"{POLICIES}")
        self.policy = policy
        self.n_slots = n_slots
        owners = tuple(owners) if owners is not None else (0,) * n_slots
        if len(owners) != n_slots:
            raise ValueError(f"owners maps {len(owners)} slots, server has "
                             f"{n_slots}")
        self.owners = owners
        # slots of each home, in slot order — ownership is chunk-contiguous
        self.slots_of: Dict[int, List[int]] = {}
        for s, h in enumerate(owners):
            self.slots_of.setdefault(h, []).append(s)
        self.homes = sorted(self.slots_of)
        self.bytes_per_token = bytes_per_token
        self.lookahead = lookahead
        self.max_skip = max_skip
        self.homes_per_pod = homes_per_pod
        sph = max(len(v) for v in self.slots_of.values())
        self.session_capacity = (session_capacity if session_capacity
                                 is not None else 4 * sph)
        # affinity yields to balance once the bound home's queue runs this
        # many entries past the least-loaded one (the hot-home relief valve)
        self.affinity_slack = (affinity_slack if affinity_slack is not None
                               else 2 * sph)
        self.prompt_pad = prompt_pad     # the server's fixed prefill bucket
        self._future: List[Tuple[float, int, object]] = []   # arrival heap
        self._seq = 0
        self._fifo: deque = deque()                          # policy="fifo"
        self._queues: Dict[int, deque] = {h: deque() for h in self.homes}
        self._bindings: Dict[object, _Binding] = {}
        self._forked: set = set()          # spill copies that must not rebind
        self._wave_sites: Dict[object, set] = {}   # session -> homes holding
        #   a copy of its cache *this wave* (a second request reuses it free)
        self.stats = ScheduleStats(
            homes={h: HomeStats() for h in self.homes})

    # ------------------------------------------------------------ submission
    def submit(self, req) -> None:
        """Enqueue a request for admission at its arrival time ``t_arrive``."""
        heapq.heappush(self._future,
                       (float(getattr(req, "t_arrive", 0.0)), self._seq, req))
        self._seq += 1

    def has_work(self) -> bool:
        return bool(self._future or self._fifo
                    or any(self._queues.values()))

    def clock(self, now: float) -> float:
        """Advance the clock to the next actionable instant (arrival jump)."""
        if self._fifo or any(self._queues.values()):
            return now
        if self._future:
            return max(now, self._future[0][0])
        return now

    def _admit(self, now: float) -> None:
        while self._future and self._future[0][0] <= now:
            _, _, req = heapq.heappop(self._future)
            self._route(req, now)

    def _load(self, h: int) -> int:
        return len(self._queues[h])

    def _route(self, req, now: float) -> None:
        if self.policy == "fifo":
            self._fifo.append(_Entry(req))
            return
        b = self._bindings.get(req.session) if req.session is not None else None
        least = min(self.homes, key=lambda h: (self._load(h), h))
        if (b is not None and b.home in self._queues
                and self._load(b.home) - self._load(least)
                <= self.affinity_slack):
            home = b.home                       # affinity: stay with the cache
        else:
            # no cached home, or the bound home is running hot: balance wins
            # (any cached prefix is dragged along — charged at admission)
            home = least
        req.home = home
        self._queues[home].append(_Entry(req))

    # ------------------------------------------------------------ relayout
    def _pod(self, home: int) -> int:
        return home // self.homes_per_pod if self.homes_per_pod else 0

    def _charge_move(self, req, new_home: int, migrate: bool = True) -> None:
        """Account the session-cache relayout implied by landing off-home.

        ``migrate=False`` is the *fork* form a spill uses when the session
        still has work queued on its bound home: the cached prefix is
        copied to the spill home for this one request (bytes charged) but
        the canonical cache — and every later request's affinity — stays
        put, so the session doesn't ping-pong home every wave.
        """
        b = self._bindings.get(req.session) if req.session is not None else None
        if b is None:
            return
        sites = self._wave_sites.setdefault(req.session, {b.home})
        if new_home not in sites and new_home != b.home:
            nbytes = b.tokens * self.bytes_per_token
            if nbytes:
                self.stats.relayout_bytes += nbytes
                self.stats.relayout_events += 1
                self.stats.homes[new_home].relayout_bytes += nbytes
                if self._pod(b.home) != self._pod(new_home):
                    self.stats.inter_pod_bytes += nbytes
                else:
                    self.stats.intra_pod_bytes += nbytes
        sites.add(new_home)
        if migrate:
            b.home = new_home                   # the cache moved with it
        elif new_home != b.home:
            self._forked.add(id(req))           # one-way copy; don't rebind

    # ------------------------------------------------------------ formation
    def _span(self, req) -> int:
        """A request's slot occupancy in wave steps: prefill rows + decode.

        With a fixed server pad bucket every wave prefills ``prompt_pad``
        rows regardless of the admitted prompts, so the span that predicts
        wave cost uses the bucket, not the raw prompt length."""
        return (self.prompt_pad or len(req.prompt)) + req.max_new

    def _pick_target(self) -> int:
        """The wave's step target: the span that maximises slot utilisation.

        Candidate targets are the distinct spans visible in the per-home
        lookahead windows; for each, the admissible work is every windowed
        entry fitting it (slot-capped per home, spill-eligible across
        homes), and the wave utilisation is that work over the capacity the
        wave would offer (``n_slots * target``).  Short decodes therefore
        batch with short decodes instead of padlocking behind a long one —
        but an *aged* entry (skipped ``max_skip`` waves) bounds staleness
        by forcing the target up to its own span.  0 = nothing queued.
        """
        windows = [list(self._queues[h])[:self.lookahead]
                   for h in self.homes]
        spans = sorted({self._span(e.req) for w in windows for e in w})
        if not spans:
            return 0
        # drain-all guard: when everything queued fits one wave, splitting
        # it by span class only buys extra prefill waves — take it all
        if (sum(len(q) for q in self._queues.values()) <= self.n_slots
                and all(len(q) <= self.lookahead
                        for q in self._queues.values())):
            return spans[-1]
        floor = max((self._span(e.req) for w in windows for e in w
                     if e.skips >= self.max_skip), default=0)
        best_t, best_eff = 0, -1.0
        for t in spans:
            if t < floor:
                continue
            busy, used, pool = 0, 0, []
            for h, w in zip(self.homes, windows):
                fits = sorted(self._span(e.req) for e in w
                              if self._span(e.req) <= t)
                cap = len(self.slots_of[h])
                busy += sum(fits[:cap])              # this home's own slots
                used += min(len(fits), cap)
                pool += fits[cap:]                   # spill-eligible excess
            busy += sum(sorted(pool)[:self.n_slots - used])
            eff = busy / (self.n_slots * t)
            if eff > best_eff + 1e-12:
                best_t, best_eff = t, eff
        return max(best_t, floor)

    def _place(self, placements: List, slot: int, req) -> None:
        """Admit one request onto one slot: charge the relayout its landing
        implies (fork vs migrate — see `_charge_move`) and keep the
        invariant that a request only ever decodes on the home owning its
        slot."""
        b = (self._bindings.get(req.session)
             if req.session is not None else None)
        migrate = not (b is not None and b.home != req.home
                       and b.home in self._queues
                       and any(x.req.session == req.session
                               for x in self._queues[b.home]))
        self._charge_move(req, req.home, migrate=migrate)
        assert self.owners[slot] == req.home         # the invariant
        placements.append((slot, req))

    def form_wave(self, now: float) -> List[Tuple[int, object]]:
        """One wave-boundary batch: ``[(slot, request), ...]`` placements.

        Every returned request decodes on the home that owns its slot; the
        caller serves the wave and then reports it back via `complete`.
        """
        self._admit(now)
        self._wave_sites = {}      # cache copies are per-wave materialised
        if self.policy == "fifo":
            wave = []
            while self._fifo and len(wave) < self.n_slots:
                req = self._fifo.popleft().req
                slot = len(wave)                 # whatever slot frees first
                req.home = self.owners[slot]
                self._charge_move(req, req.home)
                wave.append((slot, req))
            self._record_admission(wave, now)
            return wave

        placements: List[Tuple[int, object]] = []
        free: Dict[int, List[int]] = {h: list(self.slots_of[h])
                                      for h in self.homes}
        target = self._pick_target()
        if target == 0:
            self._record_admission(placements, now)
            return placements
        # 2. fill: each home admits from its own queue, front first (bounded
        # lookahead), every entry whose span fits the target — which
        # `_pick_target` already raised above every aged entry's span, so
        # nothing admissible can outgrow the wave mid-fill
        for h in self.homes:
            q = self._queues[h]
            kept: List[_Entry] = []
            scanned = 0
            while q and free[h] and scanned < self.lookahead:
                e = q.popleft()
                scanned += 1
                if self._span(e.req) <= target:
                    self._place(placements, free[h].pop(0), e.req)
                else:
                    e.skips += 1
                    kept.append(e)
            for e in reversed(kept):
                q.appendleft(e)
        # 3. spill: idle capacity pulls fitting work from other queues —
        # work conservation over strict affinity.  Donor choice minimises
        # the relayout it causes: unbound (or already-here) sessions move
        # free, bound ones cost their cached tokens; same-pod donors break
        # ties so a spill crosses DCN only when ICI has nothing to give.
        for h in self.homes:
            while free[h]:
                pick = None
                for d in self.homes:
                    if d == h:
                        continue
                    for i, e in enumerate(list(self._queues[d])
                                          [:self.lookahead]):
                        if self._span(e.req) > target:
                            continue
                        b = (self._bindings.get(e.req.session)
                             if e.req.session is not None else None)
                        cost = (0 if b is None or b.home == h
                                or h in self._wave_sites.get(e.req.session,
                                                             ())
                                else b.tokens)
                        key = (cost, self._pod(d) != self._pod(h),
                               -len(self._queues[d]), d, i)
                        if pick is None or key < pick[0]:
                            pick = (key, d, i)
                if pick is None:
                    break
                _, d, i = pick
                q = self._queues[d]
                q.rotate(-i)
                e = q.popleft()
                q.rotate(i)
                e.req.home = h
                self.stats.homes[d].spilled_out += 1
                self.stats.homes[h].spilled_in += 1
                self._place(placements, free[h].pop(0), e.req)
        placements.sort()
        self._record_admission(placements, now)
        return placements

    def _record_admission(self, placements, now: float) -> None:
        for _slot, req in placements:
            req.wait = now - float(getattr(req, "t_arrive", 0.0))
            self.stats.waits.append(req.wait)
            self.stats.homes[req.home].admitted += 1

    # ------------------------------------------------------------ completion
    def complete(self, placements, now: float, steps: float) -> None:
        """Report a served wave: update stats and session bindings (LRU)."""
        self.stats.waves += 1
        self.stats.steps += steps
        self.stats.slot_steps += self.n_slots * steps
        for _slot, req in placements:
            self.stats.served += 1
            self.stats.tokens_out += len(req.out)
            self.stats.busy_slot_steps += len(req.prompt) + len(req.out)
            if req.session is None:
                continue
            if id(req) in self._forked:
                # a spill copy: the canonical cache never left its home
                self._forked.discard(id(req))
                b = self._bindings.get(req.session)
                if b is not None:
                    b.last_used = now
                continue
            self._bindings[req.session] = _Binding(
                home=req.home, tokens=len(req.prompt) + len(req.out),
                last_used=now)
            self._evict(req.home, now)

    def _evict(self, home: int, now: float) -> None:
        """Per-home LRU compaction: drop, never migrate, over-capacity
        bindings — a cached session leaves its home only by being freed."""
        mine = [(s, b) for s, b in self._bindings.items() if b.home == home]
        while len(mine) > self.session_capacity:
            mine.sort(key=lambda sb: sb[1].last_used)
            s, _ = mine.pop(0)
            del self._bindings[s]
            self.stats.homes[home].evicted += 1

    # ------------------------------------------------------------ reporting
    def binding_home(self, session) -> Optional[int]:
        b = self._bindings.get(session)
        return b.home if b is not None else None

    def utilisation(self) -> float:
        if not self.stats.slot_steps:
            return 0.0
        return self.stats.busy_slot_steps / self.stats.slot_steps

    def summary(self) -> Dict:
        s = self.stats
        return {
            "policy": self.policy,
            "n_slots": self.n_slots,
            "n_homes": len(self.homes),
            "served": s.served,
            "tokens_out": s.tokens_out,
            "waves": s.waves,
            "steps": s.steps,
            "utilisation": round(self.utilisation(), 4),
            "wait_p50": s.wait_pct(50.0),
            "wait_p99": s.wait_pct(99.0),
            "relayout_bytes": s.relayout_bytes,
            "inter_pod_bytes": s.inter_pod_bytes,
            "intra_pod_bytes": s.intra_pod_bytes,
            "relayout_events": s.relayout_events,
            "per_home": {h: vars(hs).copy() for h, hs in s.homes.items()},
        }

    def format_summary(self) -> str:
        """The launcher's exit report: one line per home, then totals."""
        s = self.stats
        lines = [f"# scheduler policy={self.policy} slots={self.n_slots} "
                 f"homes={len(self.homes)}"
                 + (f" homes_per_pod={self.homes_per_pod}"
                    if self.homes_per_pod else ""),
                 "# home  admitted  spill_in  spill_out  evicted  "
                 "relayout_bytes"]
        for h in self.homes:
            hs = s.homes[h]
            lines.append(f"#  {h:>3} {hs.admitted:>9} {hs.spilled_in:>9} "
                         f"{hs.spilled_out:>10} {hs.evicted:>8} "
                         f"{hs.relayout_bytes:>14}")
        lines.append(
            f"# served={s.served} tokens={s.tokens_out} waves={s.waves} "
            f"steps={s.steps:.0f} util={self.utilisation():.2f} "
            f"wait_p50={s.wait_pct(50):.1f} wait_p99={s.wait_pct(99):.1f} "
            f"relayout={s.relayout_bytes}B "
            f"(inter_pod={s.inter_pod_bytes}B intra_pod={s.intra_pod_bytes}B)")
        return "\n".join(lines)


def make_scheduler(policy: str, n_slots: int, locale=None, cfg=None,
                   prompt_pad: Optional[int] = None, **kw) -> Scheduler:
    """Build a scheduler from a `Locale` — the ownership map is
    `locale.owners(n_slots)` (the engine's `chunk_bounds` applied to slots)
    and the pod split comes from the locale's (outer, ..., inner) axes."""
    owners = locale.owners(n_slots) if locale is not None else None
    homes_per_pod = None
    if locale is not None and locale.mesh is not None:
        from repro.core.homing import axis_tuple
        axes = axis_tuple(locale.axis)
        if len(axes) > 1:
            homes_per_pod = math.prod(locale.mesh.shape[a] for a in axes[1:])
    bpt = kv_bytes_per_token(cfg) if cfg is not None else 0
    return Scheduler(n_slots=n_slots, owners=owners, policy=policy,
                     bytes_per_token=bpt, homes_per_pod=homes_per_pod,
                     prompt_pad=prompt_pad, **kw)
