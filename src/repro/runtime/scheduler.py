"""Home-aware serving scheduler: admission, batching, eviction by cache home.

PR 2 homed each decode slot's KV cache on the device that computes it
(`Locale.pin_tree` over the batch-slot axis).  That localises serving
*state*; this module localises serving *decisions*.  The paper's ownership
math (`chunk_bounds`: worker w owns one contiguous chunk) is applied to
decode slots instead of sort chunks: slot s of a B-slot server on an
N-device locale is *homed* on device `Locale.owners(B)[s]`, and every
scheduling decision — which home admits a request, which requests form the
next wave, which cached session is evicted — is made in home terms.

Two policies, selected by ``Scheduler(policy=...)`` (and surfaced as
``DecodeServer(scheduler=...)`` / ``repro.launch.serve --policy``):

``"fifo"``
    The oracle: today's behaviour.  One global queue; a wave is the first B
    queued requests; a request lands on whatever slot frees first, so a
    recurring session's cached KV prefix is dragged to an arbitrary home
    almost every time it returns (cross-home relayout), and a burst of
    long decodes padlocks every slot behind the longest request.

``"homed"``
    The paper's discipline:

    * **admission** — per-home queues.  A request is routed at arrival to
      the home its session's KV already lives on (affinity), else to the
      least-loaded home; it never decodes anywhere but its assigned home.
    * **batch formation** — at each wave boundary the scheduler picks the
      step *target* that maximises slot utilisation over the visible
      queue windows (so short decodes batch with short decodes instead of
      padlocking behind a long one), then every home fills its own slots
      from its own queue, front first, with requests fitting the target.
      A request skipped ``max_skip`` waves forces the target up to its own
      span — aging bounds staleness.
    * **spill** — work conservation: a home with free slots and an empty
      (or drained) queue pulls fitting work from other homes' queues,
      cheapest relayout first (unbound sessions move free; same-pod donors
      break ties so a spill crosses DCN only when ICI has nothing to
      give), and the bytes it does move are charged — measured, not
      hidden.  A spilled session with work still queued at its bound home
      takes a one-way *copy* (the canonical cache stays put); it migrates
      only when nothing remains for it at home.
    * **eviction/compaction** — per-home LRU over session bindings.  A
      binding is only ever *dropped* on its own home, never migrated to
      another home's table: a live cache never moves off its home.

Relayout accounting is analytic, like `engine.exchange_schedule`: moving a
session with T cached tokens across homes costs ``T * kv_bytes_per_token``
bytes, split inter-pod vs intra-pod on hierarchical (pod-major) locales.
Both policies run bit-identical decode compute for the same request set
(the server's fixed ``prompt_pad`` makes each row's numerics independent
of wave composition), so the byte/step deltas are pure scheduling wins.

Every decision above is made by *pure transition functions* over an
immutable `SchedState` — ``route_t``, ``form_wave_t``, ``complete_t``:
state in, ``(state', placements, charges)`` out, the `exchange_network`
move from PR 7.  The `Scheduler` class is a thin stateful shell that
replays the charges into its stats tables; `repro.analysis.schedcheck`
(rule R9) exhaustively explores the same transitions over a small-config
lattice and certifies the invariants the docstring promises.  The
``SchedConfig.mutations`` hook exists solely for that checker's committed
known-bad fixtures — production schedulers never set it.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import (Dict, FrozenSet, List, NamedTuple, Optional, Sequence,
                    Tuple)

import numpy as np

from repro.obs import NULL_TRACER
from repro.obs import metrics as obs_metrics
from repro.runtime import kvpool
from repro.runtime.kvpool import Page

POLICIES = ("fifo", "homed")

# known-bad transition variants `analysis/fixtures.py` commits for R9;
# every name here must make `schedcheck.certify` produce a witness
MUTATIONS = ("no_aging", "drop_charge", "greedy_spill", "leak_page")


def kv_bytes_per_token(cfg) -> int:
    """Analytic KV-cache bytes one decoded token pins to a slot's home.

    The attention K+V rows per *attention* layer (`cfg.attn_layers` — the
    full stack for pure-attention families, the sparse subset for hybrids,
    empty for pure-SSM): the dominant, relayout-priced state.  SSM members
    carry O(1)-per-sequence state and are ignored, like small replicated
    leaves in `Locale.pin_tree`.
    """
    itemsize = np.dtype(cfg.dtype).itemsize
    return len(cfg.attn_layers) * 2 * cfg.num_kv_heads * cfg.head_dim \
        * itemsize


# ---------------------------------------------------------------------------
# the pure transition layer: immutable config/state, inspectable decisions
# ---------------------------------------------------------------------------
class ReqInfo(NamedTuple):
    """What a scheduling decision may observe about one request.

    ``rid`` is any unique hashable id (the shell uses its submission
    counter); ``span`` is the slot occupancy in wave steps — with a fixed
    server pad bucket every wave prefills ``prompt_pad`` rows regardless
    of the admitted prompts, so the span that predicts wave cost uses the
    bucket, not the raw prompt length.

    ``blocks`` is the prompt's cacheable page-key chain
    (`kvpool.prompt_blocks`) when the server runs a paged pool, else
    empty — the radix key the wave uses for prefix attach."""
    rid: object
    span: int
    session: object = None
    blocks: Tuple = ()


class QEntry(NamedTuple):
    req: ReqInfo
    skips: int = 0


class Binding(NamedTuple):
    """Where a session's cached KV prefix lives: its *home* and size."""
    session: object
    home: int
    tokens: int
    last_used: float


class Placement(NamedTuple):
    """One admitted request: decodes on ``home`` (which owns ``slot``);
    ``spilled_from`` names the donor queue when work conservation pulled
    it across homes, else None.  ``attached`` counts the leading prompt
    pages the home's pool already held at wave start — prefill compute
    the server skips by attaching pooled KV instead of recomputing."""
    slot: int
    rid: object
    home: int
    spilled_from: Optional[int] = None
    attached: int = 0


class Charge(NamedTuple):
    """One session-cache relayout the wave decided to pay.  ``migrate``
    distinguishes a rebind (the canonical cache moved) from the one-way
    *fork* copy a spill takes when the session still has work queued at
    its bound home."""
    rid: object
    session: object
    src: int
    dst: int
    tokens: int
    nbytes: int
    inter_pod: bool
    migrate: bool


class Charges(NamedTuple):
    """Everything `form_wave_t` decided to pay and why: the replayable
    accounting record the shell turns into stats and R9 audits move-by-
    move against an independent model."""
    moves: Tuple[Charge, ...]
    target: int
    floor: int


@dataclass(frozen=True)
class SchedConfig:
    """The immutable decision parameters (`Scheduler.__init__` validated).

    ``mutations`` enables committed known-bad transition variants for the
    R9 checker (`MUTATIONS`); production configs leave it empty."""
    policy: str = "fifo"
    n_slots: int = 1
    owners: Tuple[int, ...] = (0,)
    bytes_per_token: int = 0
    lookahead: int = 8
    max_skip: int = 4
    homes_per_pod: Optional[int] = None
    session_capacity: int = 4
    affinity_slack: int = 2
    page_capacity: int = 0       # pooled KV pages per home; 0 = no pool
    mutations: FrozenSet[str] = frozenset()

    @property
    def homes(self) -> Tuple[int, ...]:
        return tuple(sorted(set(self.owners)))

    @property
    def slots_of(self) -> Dict[int, List[int]]:
        out: Dict[int, List[int]] = {}
        for s, h in enumerate(self.owners):
            out.setdefault(h, []).append(s)
        return out

    def pod(self, home: int) -> int:
        return home // self.homes_per_pod if self.homes_per_pod else 0


@dataclass(frozen=True)
class SchedState:
    """The entire mutable world a decision may read, as immutable tuples.

    ``queues`` maps home -> arrival-ordered entries; ``bindings`` keeps
    the session table in *insertion order* (dict semantics: an update
    keeps its slot, a new binding appends) because LRU eviction ties on
    ``last_used`` break by that order; ``forked`` holds rids of in-flight
    spill copies that must not rebind at completion; ``pools`` maps
    home -> its paged-KV pool (`kvpool.Page` tuples) when the config
    runs one (``page_capacity > 0``)."""
    queues: Tuple[Tuple[int, Tuple[QEntry, ...]], ...] = ()
    fifo: Tuple[ReqInfo, ...] = ()
    bindings: Tuple[Binding, ...] = ()
    forked: FrozenSet[object] = frozenset()
    pools: Tuple[Tuple[int, Tuple[Page, ...]], ...] = ()

    def queue(self, home: int) -> Tuple[QEntry, ...]:
        for h, q in self.queues:
            if h == home:
                return q
        return ()

    def pool(self, home: int) -> Tuple[Page, ...]:
        for h, p in self.pools:
            if h == home:
                return p
        return ()

    def binding(self, session) -> Optional[Binding]:
        if session is None:
            return None
        for b in self.bindings:
            if b.session == session:
                return b
        return None

    @property
    def pending(self) -> int:
        return len(self.fifo) + sum(len(q) for _, q in self.queues)


def initial_state(cfg: SchedConfig) -> SchedState:
    pools = tuple((h, ()) for h in cfg.homes) if cfg.page_capacity > 0 \
        else ()
    return SchedState(queues=tuple((h, ()) for h in cfg.homes),
                      pools=pools)


def _queues_dict(state: SchedState) -> Dict[int, List[QEntry]]:
    return {h: list(q) for h, q in state.queues}


def _bindings_dict(state: SchedState) -> Dict[object, Binding]:
    return {b.session: b for b in state.bindings}


def _pools_dict(state: SchedState) -> Dict[int, Tuple[Page, ...]]:
    return {h: p for h, p in state.pools}


def _pool_refs(state: SchedState) -> Dict[int, int]:
    """Per-home live page refcounts — the traced pool-identity quantity."""
    return {h: sum(pg.refs for pg in p) for h, p in state.pools}


def _pack(queues: Dict[int, List[QEntry]], fifo: List[ReqInfo],
          bindings: Dict[object, Binding], forked: FrozenSet[object],
          pools: Dict[int, Tuple[Page, ...]]) -> SchedState:
    return SchedState(
        queues=tuple((h, tuple(q)) for h, q in queues.items()),
        fifo=tuple(fifo), bindings=tuple(bindings.values()), forked=forked,
        pools=tuple((h, tuple(p)) for h, p in pools.items()))


def route_t(cfg: SchedConfig, state: SchedState,
            req: ReqInfo) -> Tuple[SchedState, int]:
    """Admit one arrival: returns ``(state', home)``.  Affinity keeps a
    bound session with its cache unless its home's queue runs
    ``affinity_slack`` entries past the least-loaded one (the hot-home
    relief valve); an unbound request always balances."""
    if cfg.policy == "fifo":
        return _pack(_queues_dict(state), list(state.fifo) + [req],
                     _bindings_dict(state), state.forked,
                     _pools_dict(state)), -1
    queues = _queues_dict(state)
    b = state.binding(req.session)
    least = min(cfg.homes, key=lambda h: (len(queues[h]), h))
    if (b is not None and b.home in queues
            and len(queues[b.home]) - len(queues[least])
            <= cfg.affinity_slack):
        home = b.home                       # affinity: stay with the cache
    else:
        # no cached home, or the bound home is running hot: balance wins
        # (any cached prefix is dragged along — charged at admission)
        home = least
    queues[home].append(QEntry(req))
    return _pack(queues, list(state.fifo), _bindings_dict(state),
                 state.forked, _pools_dict(state)), home


class _WaveCtx:
    """Mutable scratch shared by one `form_wave_t` call: the evolving
    binding table, the per-wave cache-copy sites, the per-home page
    pools, and the move record."""

    def __init__(self, cfg: SchedConfig, state: SchedState,
                 now: float = 0.0):
        self.cfg = cfg
        self.now = now
        self.bindings = _bindings_dict(state)
        self.forked = set(state.forked)
        self.sites: Dict[object, set] = {}   # session -> homes holding a
        #   copy of its cache *this wave* (a second request reuses it free)
        self.moves: List[Charge] = []
        self.pools = _pools_dict(state)
        # the attachable key set is frozen at wave start: a page a wave-
        # mate inserts *this wave* is refcount-shared but its content is
        # not in the home's store yet, so it cannot be attached
        self.known = {h: frozenset(pg.key for pg in p)
                      for h, p in self.pools.items()}

    def attach_pages(self, req: ReqInfo, home: int) -> int:
        """Pin ``req``'s block chain into ``home``'s pool; returns the
        attachable longest-prefix hit.  Attach never crosses homes: the
        only pool consulted is the landing home's own — a prefix cached
        elsewhere is invisible here and gets recomputed (or the session
        pays the fork/migrate charge that brought it, which `charge_move`
        already recorded)."""
        if not req.blocks or self.cfg.page_capacity <= 0:
            return 0
        pages, hit = kvpool.acquire(
            tuple(self.pools.get(home, ())), req.blocks,
            self.cfg.page_capacity, self.now,
            self.known.get(home, frozenset()))
        self.pools[home] = pages
        return hit

    def charge_move(self, req: ReqInfo, new_home: int,
                    migrate: bool = True) -> None:
        """Account the session-cache relayout implied by landing off-home.

        ``migrate=False`` is the *fork* form a spill uses when the session
        still has work queued on its bound home: the cached prefix is
        copied to the spill home for this one request (bytes charged) but
        the canonical cache — and every later request's affinity — stays
        put, so the session doesn't ping-pong home every wave.
        """
        b = self.bindings.get(req.session) if req.session is not None \
            else None
        if b is None:
            return
        sites = self.sites.setdefault(req.session, {b.home})
        if new_home not in sites and new_home != b.home:
            if "drop_charge" not in self.cfg.mutations:
                self.moves.append(Charge(
                    rid=req.rid, session=req.session, src=b.home,
                    dst=new_home, tokens=b.tokens,
                    nbytes=b.tokens * self.cfg.bytes_per_token,
                    inter_pod=self.cfg.pod(b.home) != self.cfg.pod(new_home),
                    migrate=migrate))
        sites.add(new_home)
        if migrate:
            self.bindings[b.session] = b._replace(home=new_home)
        elif new_home != b.home:
            self.forked.add(req.rid)        # one-way copy; don't rebind


def _pick_target(cfg: SchedConfig, queues: Dict[int, List[QEntry]],
                 free_of: Dict[int, List[int]]) -> Tuple[int, int]:
    """The wave's step target: the span that maximises slot utilisation.

    Candidate targets are the distinct spans visible in the per-home
    lookahead windows; for each, the admissible work is every windowed
    entry fitting it (capped by the *free* slots per home — under
    continuous batching a wave refills only the slots that drained —
    spill-eligible across homes), and the wave utilisation is that work
    over the capacity the wave would offer (``free * target``).  Short
    decodes therefore batch with short decodes instead of padlocking
    behind a long one — but an *aged* entry (skipped ``max_skip`` waves)
    bounds staleness by forcing the target up to its own span.  Returns
    ``(target, floor)``; target 0 = nothing queued.
    """
    n_free = sum(len(s) for s in free_of.values())
    windows = [queues[h][:cfg.lookahead] for h in cfg.homes]
    spans = sorted({e.req.span for w in windows for e in w})
    if not spans or n_free == 0:
        return 0, 0
    # drain-all guard: when everything queued fits one wave, splitting
    # it by span class only buys extra prefill waves — take it all
    if (sum(len(q) for q in queues.values()) <= n_free
            and all(len(q) <= cfg.lookahead for q in queues.values())):
        return spans[-1], 0
    floor = 0 if "no_aging" in cfg.mutations else \
        max((e.req.span for w in windows for e in w
             if e.skips >= cfg.max_skip), default=0)
    best_t, best_eff = 0, -1.0
    for t in spans:
        if t < floor:
            continue
        busy, used, pool = 0, 0, []
        for h, w in zip(cfg.homes, windows):
            fits = sorted(e.req.span for e in w if e.req.span <= t)
            cap = len(free_of.get(h, ()))
            busy += sum(fits[:cap])              # this home's own slots
            used += min(len(fits), cap)
            pool += fits[cap:]                   # spill-eligible excess
        busy += sum(sorted(pool)[:n_free - used])
        eff = busy / (n_free * t)
        if eff > best_eff + 1e-12:
            best_t, best_eff = t, eff
    return max(best_t, floor), floor


def _place(ctx: _WaveCtx, queues: Dict[int, List[QEntry]],
           placements: List[Placement], slot: int, req: ReqInfo,
           home: int, spilled_from: Optional[int] = None) -> None:
    """Admit one request onto one slot: charge the relayout its landing
    implies (fork vs migrate — see `_WaveCtx.charge_move`), pin its
    prompt pages into the landing home's pool, and keep the invariant
    that a request only ever decodes on the home owning its slot."""
    b = ctx.bindings.get(req.session) if req.session is not None else None
    migrate = not (b is not None and b.home != home
                   and b.home in queues
                   and any(x.req.session == req.session
                           for x in queues[b.home]))
    ctx.charge_move(req, home, migrate=migrate)
    assert ctx.cfg.owners[slot] == home          # the invariant
    attached = ctx.attach_pages(req, home)
    placements.append(Placement(slot, req.rid, home, spilled_from,
                                attached))


def form_wave_t(cfg: SchedConfig, state: SchedState,
                free: Optional[Sequence[int]] = None, now: float = 0.0
                ) -> Tuple[SchedState, Tuple[Placement, ...], Charges]:
    """One wave-boundary batch, purely: ``(state', placements, charges)``.

    ``free`` is the set of slot indices available this wave — ``None``
    means all of them (the legacy whole-wave boundary); under continuous
    batching the server passes just the slots whose requests drained, so
    a freed slot refills mid-wave while its neighbours keep decoding.

    Placements come back in *decision order* (fill before spill) so a
    checker can replay them against the pre-wave queues; the shell sorts
    by slot before reporting.  Every placement decodes on the home that
    owns its slot, every cache byte the decisions move is a `Charge` in
    ``charges.moves``, and every prompt page a placement pins into its
    home's pool is refcounted in ``state'.pools`` — the complete
    accounting record.
    """
    free_slots = sorted(range(cfg.n_slots) if free is None else free)
    if cfg.policy == "fifo":
        ctx = _WaveCtx(cfg, state, now)
        fifo = list(state.fifo)
        placements: List[Placement] = []
        for slot in free_slots:                  # whatever slot frees first
            if not fifo:
                break
            req = fifo.pop(0)
            ctx.charge_move(req, cfg.owners[slot])
            attached = ctx.attach_pages(req, cfg.owners[slot])
            placements.append(Placement(slot, req.rid, cfg.owners[slot],
                                        None, attached))
        return (_pack(_queues_dict(state), fifo, ctx.bindings,
                      frozenset(ctx.forked), ctx.pools),
                tuple(placements), Charges(tuple(ctx.moves), 0, 0))

    ctx = _WaveCtx(cfg, state, now)
    queues = _queues_dict(state)
    placements = []
    free_set = set(free_slots)
    free_of: Dict[int, List[int]] = {
        h: [s for s in slots if s in free_set]
        for h, slots in cfg.slots_of.items()}
    if free is not None:
        # continuous refill: per-slot position clocks removed the
        # alignment constraint, so span classes no longer gate admission
        # — any queued span can take any free slot without padlocking
        # its neighbours.  Admit front-first; locality still decides
        # *where* (fill own home, then charged spill).
        windows = [queues[h][:cfg.lookahead] for h in cfg.homes]
        target = max((e.req.span for w in windows for e in w), default=0)
        floor = 0
    else:
        target, floor = _pick_target(cfg, queues, free_of)
    if target == 0:
        return state, (), Charges((), 0, floor)
    # 2. fill: each home admits from its own queue, front first (bounded
    # lookahead), every entry whose span fits the target — which
    # `_pick_target` already raised above every aged entry's span, so
    # nothing admissible can outgrow the wave mid-fill
    for h in cfg.homes:
        q = queues[h]
        kept: List[QEntry] = []
        scanned = 0
        while q and free_of[h] and scanned < cfg.lookahead:
            e = q.pop(0)
            scanned += 1
            if e.req.span <= target:
                _place(ctx, queues, placements, free_of[h].pop(0), e.req,
                       h)
            else:
                kept.append(e._replace(skips=e.skips + 1))
        q[:0] = kept
    # 3. spill: idle capacity pulls fitting work from other queues —
    # work conservation over strict affinity.  Donor choice minimises
    # the relayout it causes: unbound (or already-here) sessions move
    # free, bound ones cost their cached tokens; same-pod donors break
    # ties so a spill crosses DCN only when ICI has nothing to give.
    greedy = "greedy_spill" in cfg.mutations
    for h in cfg.homes:
        while free_of[h]:
            pick = None
            for d in cfg.homes:
                if d == h:
                    continue
                for i, e in enumerate(queues[d][:cfg.lookahead]):
                    if e.req.span > target:
                        continue
                    b = (ctx.bindings.get(e.req.session)
                         if e.req.session is not None else None)
                    cost = (0 if b is None or b.home == h
                            or h in ctx.sites.get(e.req.session, ())
                            else b.tokens)
                    key = (cost, cfg.pod(d) != cfg.pod(h),
                           -len(queues[d]), d, i)
                    if pick is None or (not greedy and key < pick[0]):
                        pick = (key, d, i)
                if greedy and pick is not None:
                    break
            if pick is None:
                break
            _, d, i = pick
            e = queues[d].pop(i)
            _place(ctx, queues, placements, free_of[h].pop(0), e.req, h,
                   spilled_from=d)
    return (_pack(queues, list(state.fifo), ctx.bindings,
                  frozenset(ctx.forked), ctx.pools),
            tuple(placements), Charges(tuple(ctx.moves), target, floor))


class Served(NamedTuple):
    """What completion reports per request: its final cached size and
    the prompt-page chain it pinned at formation (released here)."""
    rid: object
    session: object
    home: int
    tokens: int
    blocks: Tuple = ()


def complete_t(cfg: SchedConfig, state: SchedState,
               served: Sequence[Served], now: float
               ) -> Tuple[SchedState, Tuple[Binding, ...]]:
    """Rebind completed sessions (LRU-touch fork copies instead), release
    the page refcounts formation acquired, and run per-home LRU
    compaction: returns ``(state', evicted_bindings)``.  Evicted bindings
    are *dropped on their own home*, never migrated — a cached session
    leaves its home only by being freed."""
    bindings = _bindings_dict(state)
    forked = set(state.forked)
    pools = _pools_dict(state)
    evicted: List[Binding] = []
    for sv in served:
        # unpin the prompt pages this request held in flight (absent keys
        # tolerated: a mid-flight invalidation already dropped them)
        if sv.blocks and sv.home in pools \
                and "leak_page" not in cfg.mutations:
            pools[sv.home] = kvpool.release(pools[sv.home], sv.blocks, now)
        if sv.session is None:
            continue
        if sv.rid in forked:
            # a spill copy: the canonical cache never left its home
            forked.discard(sv.rid)
            b = bindings.get(sv.session)
            if b is not None:
                bindings[sv.session] = b._replace(last_used=now)
            continue
        bindings[sv.session] = Binding(sv.session, sv.home, sv.tokens, now)
        # sort once, take the over-capacity prefix oldest-first (ties
        # break by insertion order — the sort is stable over the dict)
        mine = [b for b in bindings.values() if b.home == sv.home]
        if len(mine) > cfg.session_capacity:
            mine.sort(key=lambda b: b.last_used)
            for b in mine[:len(mine) - cfg.session_capacity]:
                del bindings[b.session]
                evicted.append(b)
    return _pack(_queues_dict(state), list(state.fifo), bindings,
                 frozenset(forked), pools), tuple(evicted)


# ---------------------------------------------------------------------------
# the stateful shell: arrival clock, request objects, stats
# ---------------------------------------------------------------------------
@dataclass
class HomeStats:
    admitted: int = 0
    spilled_in: int = 0
    spilled_out: int = 0
    evicted: int = 0
    relayout_bytes: int = 0      # bytes charged for sessions moved ONTO this home


@dataclass
class ScheduleStats:
    """Deterministic per-run accounting (wall clock lives in the bench)."""
    homes: Dict[int, HomeStats] = field(default_factory=dict)
    waves: int = 0
    steps: float = 0.0           # wave cost units: prefill rows + decode steps
    slot_steps: float = 0.0      # n_slots * steps (capacity offered)
    busy_slot_steps: float = 0.0 # sum over served reqs of their own span
    waits: List[float] = field(default_factory=list)
    relayout_bytes: int = 0      # total cross-home session-cache movement
    inter_pod_bytes: int = 0     # subset crossing a pod boundary
    intra_pod_bytes: int = 0
    relayout_events: int = 0
    served: int = 0
    tokens_out: int = 0
    affinity_hits: int = 0       # placements landing on the session's home
    pages_attached: int = 0      # pooled prompt pages reused (prefill skipped)
    prefix_hits_full: int = 0    # placements attaching their whole chain
    prefix_hits_partial: int = 0 # placements attaching a proper prefix

    def wait_pct(self, q: float) -> float:
        if not self.waits:
            return 0.0
        return float(np.percentile(np.asarray(self.waits), q))


class Scheduler:
    """Route, batch and evict decode requests by KV-cache home.

    A thin shell over the pure transitions above: it owns the arrival
    heap, the `Request` objects and the stats tables; every decision is
    `route_t`/`form_wave_t`/`complete_t` on ``self.state``, and the stats
    are replayed from the `Charges` those transitions return.

    ``owners`` maps slot index -> home-device index (``Locale.owners``:
    `chunk_bounds` applied to slots).  ``homes_per_pod`` is the number of
    homes per pod on a hierarchical (pod-major) locale — it only affects
    the inter/intra-pod split of the relayout bytes and the spill donor
    preference; ``None`` means a flat (single-distance-class) locale.
    """

    def __init__(self, n_slots: int, owners: Optional[Sequence[int]] = None,
                 policy: str = "fifo", bytes_per_token: int = 0,
                 lookahead: int = 8, max_skip: int = 4,
                 homes_per_pod: Optional[int] = None,
                 session_capacity: Optional[int] = None,
                 affinity_slack: Optional[int] = None,
                 prompt_pad: Optional[int] = None,
                 page_size: int = 0, page_capacity: int = 0,
                 tracer=None):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; want one of "
                             f"{POLICIES}")
        owners = tuple(owners) if owners is not None else (0,) * n_slots
        if len(owners) != n_slots:
            raise ValueError(f"owners maps {len(owners)} slots, server has "
                             f"{n_slots}")
        if page_capacity > 0 and page_size <= 0:
            raise ValueError("page_capacity needs a positive page_size")
        sph = max(len(v) for v in SchedConfig(owners=owners).slots_of
                  .values())
        self.cfg = SchedConfig(
            policy=policy, n_slots=n_slots, owners=owners,
            bytes_per_token=bytes_per_token, lookahead=lookahead,
            max_skip=max_skip, homes_per_pod=homes_per_pod,
            session_capacity=(session_capacity if session_capacity
                              is not None else 4 * sph),
            # affinity yields to balance once the bound home's queue runs
            # this many entries past the least-loaded one (the hot-home
            # relief valve)
            affinity_slack=(affinity_slack if affinity_slack is not None
                            else 2 * sph),
            page_capacity=page_capacity)
        self.prompt_pad = prompt_pad     # the server's fixed prefill bucket
        self.page_size = page_size       # tokens per pooled KV page
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = obs_metrics.MetricsRegistry()
        self.state = initial_state(self.cfg)
        self._future: List[Tuple[float, int, object]] = []   # arrival heap
        self._seq = 0
        self._uid = 0                        # monotone ReqInfo.rid source
        self._reqs: Dict[int, object] = {}   # uid -> queued Request
        self.stats = ScheduleStats(
            homes={h: HomeStats() for h in self.homes})

    # config views (the shell's public surface predates SchedConfig)
    policy = property(lambda self: self.cfg.policy)
    n_slots = property(lambda self: self.cfg.n_slots)
    owners = property(lambda self: self.cfg.owners)
    bytes_per_token = property(lambda self: self.cfg.bytes_per_token)
    lookahead = property(lambda self: self.cfg.lookahead)
    max_skip = property(lambda self: self.cfg.max_skip)
    homes_per_pod = property(lambda self: self.cfg.homes_per_pod)
    session_capacity = property(lambda self: self.cfg.session_capacity)
    affinity_slack = property(lambda self: self.cfg.affinity_slack)
    slots_of = property(lambda self: self.cfg.slots_of)
    page_capacity = property(lambda self: self.cfg.page_capacity)

    @property
    def homes(self) -> List[int]:
        return list(self.cfg.homes)

    # ------------------------------------------------------------ submission
    def submit(self, req) -> None:
        """Enqueue a request for admission at its arrival time ``t_arrive``."""
        heapq.heappush(self._future,
                       (float(getattr(req, "t_arrive", 0.0)), self._seq, req))
        self._seq += 1

    def has_work(self) -> bool:
        return bool(self._future) or self.state.pending > 0

    def clock(self, now: float) -> float:
        """Advance the clock to the next actionable instant (arrival jump)."""
        if self.state.pending:
            return now
        if self._future:
            return max(now, self._future[0][0])
        return now

    def _span(self, req) -> int:
        return (self.prompt_pad or len(req.prompt)) + req.max_new

    def _admit(self, now: float) -> None:
        while self._future and self._future[0][0] <= now:
            _, _, req = heapq.heappop(self._future)
            uid, self._uid = self._uid, self._uid + 1
            blocks = (kvpool.prompt_blocks(req.prompt, self.page_size)
                      if self.cfg.page_capacity > 0 else ())
            info = ReqInfo(rid=uid, span=self._span(req),
                           session=req.session, blocks=blocks)
            req._sched_blocks = blocks
            self._reqs[uid] = req
            pre_b = self.state.binding(req.session)
            self.state, home = route_t(self.cfg, self.state, info)
            if home >= 0:
                req.home = home
            if self.tracer.enabled:
                self.tracer.event(
                    "sched.route", cat="sched", rid=uid,
                    session=req.session, home=home, now=now,
                    span=info.span, blocks=len(blocks),
                    affinity=(pre_b is not None and home == pre_b.home))

    # ------------------------------------------------------------ formation
    def form_wave(self, now: float,
                  free_slots: Optional[Sequence[int]] = None
                  ) -> List[Tuple[int, object]]:
        """One wave-boundary batch: ``[(slot, request), ...]`` placements.

        ``free_slots`` restricts the wave to the slots that actually
        drained (continuous batching); ``None`` offers every slot — the
        legacy whole-wave boundary.  Every returned request decodes on
        the home that owns its slot; the caller serves the wave and then
        reports it back via `complete`.
        """
        tr = self.tracer
        with tr.span("sched.form_wave", cat="sched", now=now,
                     free=(len(free_slots) if free_slots is not None
                           else self.n_slots)) as sp:
            self._admit(now)
            pre_homes = {b.session: b.home for b in self.state.bindings}
            pre_refs = _pool_refs(self.state)
            self.state, placements, charges = form_wave_t(
                self.cfg, self.state, free=free_slots, now=now)
            # the wave id the events carry: stats.waves is bumped below
            # only for non-empty waves, so this is the id it will get
            wid = self.stats.waves + (1 if placements else 0)
            charged = 0
            for c in charges.moves:
                if c.nbytes:
                    self.stats.relayout_bytes += c.nbytes
                    self.stats.relayout_events += 1
                    self.stats.homes[c.dst].relayout_bytes += c.nbytes
                    if c.inter_pod:
                        self.stats.inter_pod_bytes += c.nbytes
                    else:
                        self.stats.intra_pod_bytes += c.nbytes
                charged += c.nbytes
                tr.event("sched.charge", cat="sched", wave=wid, rid=c.rid,
                         session=c.session, src=c.src, dst=c.dst,
                         tokens=c.tokens, nbytes=c.nbytes,
                         inter_pod=c.inter_pod, migrate=c.migrate)
            # pool refs the wave's attaches pinned (reconcile identity:
            # acquires - releases - invalidations == live refs)
            for h, refs in _pool_refs(self.state).items():
                if refs - pre_refs.get(h, 0) > 0:
                    tr.event("pool.acquire", cat="pool", wave=wid, home=h,
                             refs=refs - pre_refs.get(h, 0))
            wave = []
            for p in placements:
                req = self._reqs.pop(p.rid)
                req.home = p.home
                req._sched_uid = p.rid      # complete() keys forked by it
                req._attached = p.attached  # pages the server may attach
                nblk = len(getattr(req, "_sched_blocks", ()))
                if p.attached:
                    self.stats.pages_attached += p.attached
                    if p.attached == nblk:
                        self.stats.prefix_hits_full += 1
                    else:
                        self.stats.prefix_hits_partial += 1
                if p.spilled_from is not None:
                    self.stats.homes[p.spilled_from].spilled_out += 1
                    self.stats.homes[p.home].spilled_in += 1
                elif (self.cfg.policy == "homed"
                      and pre_homes.get(req.session) == p.home):
                    self.stats.affinity_hits += 1
                # decision order matters: the reconciler replays the
                # same-wave cache-copy sites from this event sequence
                tr.event("sched.place", cat="sched", wave=wid, rid=p.rid,
                         slot=p.slot, home=p.home, session=req.session,
                         spilled_from=p.spilled_from, attached=p.attached,
                         blocks=nblk, bound_home=pre_homes.get(req.session),
                         wait=now - float(getattr(req, "t_arrive", 0.0)))
                wave.append((p.slot, req))
            wave.sort(key=lambda sr: sr[0])
            if wave:
                self.stats.waves += 1
            waits = []
            for _slot, req in wave:
                req.wait = now - float(getattr(req, "t_arrive", 0.0))
                self.stats.waits.append(req.wait)
                waits.append(req.wait)
                self.stats.homes[req.home].admitted += 1
            sp.set(wave=wid, target=charges.target, floor=charges.floor,
                   placed=len(placements), charged_bytes=charged)
            if wave:
                self.metrics.record_wave(
                    self.cfg, self.state, wid, now, charges.target,
                    placements, waits, self.utilisation(), tracer=tr)
        return wave

    # ------------------------------------------------------------ completion
    def tick(self, units: float) -> None:
        """Account wave-cost units as they happen (continuous batching:
        there is no single per-wave cost — prefill page levels and decode
        steps interleave across refills)."""
        self.stats.steps += units
        self.stats.slot_steps += self.n_slots * units

    def complete(self, placements, now: float, steps: float = 0.0) -> None:
        """Report served requests: update stats, session bindings (LRU)
        and page refcounts.  ``steps`` adds a whole-wave cost for legacy
        callers; continuous servers account costs via `tick` and complete
        requests as their slots drain (possibly a subset of a wave)."""
        if steps:
            self.tick(steps)
        tr = self.tracer
        with tr.span("sched.complete", cat="sched", now=now,
                     served=len(placements)) as sp:
            served = []
            for _slot, req in placements:
                self.stats.served += 1
                self.stats.tokens_out += len(req.out)
                self.stats.busy_slot_steps += len(req.prompt) + len(req.out)
                served.append(Served(
                    rid=getattr(req, "_sched_uid", id(req)),
                    session=req.session,
                    home=req.home, tokens=len(req.prompt) + len(req.out),
                    blocks=getattr(req, "_sched_blocks", ())))
            pre_refs = _pool_refs(self.state)
            self.state, evicted = complete_t(self.cfg, self.state, served,
                                             now)
            for b in evicted:
                self.stats.homes[b.home].evicted += 1
                tr.event("sched.evict", cat="sched", session=b.session,
                         home=b.home, now=now)
            post_refs = _pool_refs(self.state)
            for h, refs in pre_refs.items():
                dropped = refs - post_refs.get(h, 0)
                if dropped > 0:
                    tr.event("pool.release", cat="pool", home=h,
                             refs=dropped, now=now)
            sp.set(evicted=len(evicted))

    # ------------------------------------------------------------ page pool
    def pool_keys(self, home: int) -> List[object]:
        """The block keys ``home``'s pool currently holds (server pruning)."""
        return [p.key for p in self.state.pool(home)]

    def invalidate_pages(self, home: Optional[int] = None) -> int:
        """Force-drop pooled pages (all homes when ``home`` is None)
        regardless of refcounts — the fleet-reliability path after a home
        loses its device state.  In-flight requests finish on their
        private cache copies (their later release is tolerated); the
        session's next request re-enters as a fresh, charged prefill.
        Returns the number of pages dropped."""
        pools = _pools_dict(self.state)
        dropped = 0
        for h in list(pools):
            if home is not None and h != home:
                continue
            npages = len(pools[h])
            if npages:
                self.tracer.event(
                    "pool.invalidate", cat="pool", home=h, pages=npages,
                    refs=sum(pg.refs for pg in pools[h]))
            dropped += npages
            pools[h] = kvpool.invalidate(pools[h])
        self.state = _pack(_queues_dict(self.state), list(self.state.fifo),
                           _bindings_dict(self.state), self.state.forked,
                           pools)
        return dropped

    # ------------------------------------------------------------ reporting
    def binding_home(self, session) -> Optional[int]:
        b = self.state.binding(session)
        return b.home if b is not None else None

    def utilisation(self) -> float:
        if not self.stats.slot_steps:
            return 0.0
        return self.stats.busy_slot_steps / self.stats.slot_steps

    def prefill_rows_saved(self) -> float:
        """Prefill compute avoided by page attach, in the bench's row
        units: one 'row' = one request's ``prompt_pad``-token prefill, so
        attached pages convert at ``page_size / prompt_pad`` rows each."""
        if not self.prompt_pad or not self.page_size:
            return 0.0
        return self.stats.pages_attached * self.page_size / self.prompt_pad

    def summary(self) -> Dict:
        """The canonical summary dict (see `repro.obs.metrics.summarise`).
        One rendering path: the launcher's human report, bench_serve's CSV
        rows and the trace's ``sched.summary`` event all read this dict."""
        return obs_metrics.summarise(self)

    def format_summary(self) -> str:
        """The launcher's exit report: one line per home, then totals."""
        return obs_metrics.format_summary(self.summary())

    def emit_summary(self) -> Dict:
        """Emit the final summary into the trace (the reconciliation
        target) and return it.  A trace may contain several serving runs;
        each ``sched.summary`` event closes one reconciliation segment."""
        summary = self.summary()
        self.tracer.event("sched.summary", cat="sched", **summary)
        return summary


def make_scheduler(policy: str, n_slots: int, locale=None, cfg=None,
                   prompt_pad: Optional[int] = None, **kw) -> Scheduler:
    """Build a scheduler from a `Locale` — the ownership map is
    `locale.owners(n_slots)` (the engine's `chunk_bounds` applied to slots)
    and the pod split comes from the locale's (outer, ..., inner) axes."""
    owners = locale.owners(n_slots) if locale is not None else None
    homes_per_pod = None
    if locale is not None and locale.mesh is not None:
        from repro.core.homing import axis_tuple
        axes = axis_tuple(locale.axis)
        if len(axes) > 1:
            homes_per_pod = math.prod(locale.mesh.shape[a] for a in axes[1:])
    bpt = kv_bytes_per_token(cfg) if cfg is not None else 0
    return Scheduler(n_slots=n_slots, owners=owners, policy=policy,
                     bytes_per_token=bpt, homes_per_pod=homes_per_pod,
                     prompt_pad=prompt_pad, **kw)
