from repro.runtime.trainer import Trainer, TrainerConfig
from repro.runtime.ft import Supervisor

__all__ = ["Trainer", "TrainerConfig", "Supervisor"]
