from repro.runtime.trainer import Trainer, TrainerConfig
from repro.runtime.ft import Supervisor
from repro.runtime.scheduler import (Scheduler, kv_bytes_per_token,
                                     make_scheduler)

__all__ = ["Trainer", "TrainerConfig", "Supervisor", "Scheduler",
           "kv_bytes_per_token", "make_scheduler"]
