"""Per-home metrics registry: wave-boundary snapshots + ONE rendering path.

The scheduler owns a `MetricsRegistry`; at every wave boundary it calls
`record_wave`, which snapshots the per-home state a dashboard would plot
— queue depths, bound sessions, KV-pool pages / live refs, the wave's
step target and admitted waits, utilisation so far — and emits the same
numbers as tracer gauges so a trace carries the full time series.

`summarise(scheduler)` folds the final stats + snapshots into the ONE
canonical summary dict every consumer renders from:

* ``format_summary(summary)``  — the human exit report
  (`launch/serve.py`, `Scheduler.format_summary`),
* ``bench_rows(name, summary, wall_us)`` — the ``name,us,derived`` CSV
  rows `benchmarks/bench_serve.py` prints (and `compare.py` gates),
* the ``sched.summary`` trace event (`Scheduler.emit_summary`) that
  `repro.obs.reconcile` checks every traced counter against.

Because all three render the same dict, a stat can't drift between the
launcher's print, the bench baseline and the trace — the reconciliation
identities would catch it.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.obs.tracelog import NULL_TRACER


@dataclass(frozen=True)
class WaveSnapshot:
    """One wave boundary, as a dashboard row."""
    wave: int
    now: float
    target: int
    placed: int
    queue_depth: Dict[int, int]
    bound_sessions: Dict[int, int]
    pool_pages: Dict[int, int]
    pool_refs: Dict[int, int]
    waits: Tuple[float, ...]
    utilisation: float


@dataclass
class MetricsRegistry:
    """Wave-boundary snapshots + derived per-home aggregates."""

    snapshots: List[WaveSnapshot] = field(default_factory=list)

    def record_wave(self, cfg, state, wave: int, now: float, target: int,
                    placements, waits, utilisation: float,
                    tracer=NULL_TRACER) -> WaveSnapshot:
        """Snapshot one formed wave from the scheduler's (cfg, state').

        ``state`` is the post-wave `SchedState`; queue depths and pool
        contents are therefore what the *next* decision will see — the
        steady-state backlog a dashboard wants.
        """
        bound: Dict[int, int] = {h: 0 for h in cfg.homes}
        for b in state.bindings:
            bound[b.home] = bound.get(b.home, 0) + 1
        snap = WaveSnapshot(
            wave=wave, now=now, target=target, placed=len(placements),
            queue_depth={h: len(q) for h, q in state.queues},
            bound_sessions=bound,
            pool_pages={h: len(p) for h, p in state.pools},
            pool_refs={h: sum(pg.refs for pg in p)
                       for h, p in state.pools},
            waits=tuple(waits), utilisation=utilisation)
        self.snapshots.append(snap)
        if tracer.enabled:
            tracer.gauge("sched.queue_depth",
                         sum(snap.queue_depth.values()), cat="metrics",
                         per_home=snap.queue_depth, wave=wave, now=now)
            tracer.gauge("sched.bound_sessions", sum(bound.values()),
                         cat="metrics", per_home=bound, wave=wave)
            if snap.pool_pages:
                tracer.gauge("pool.pages", sum(snap.pool_pages.values()),
                             cat="metrics", per_home=snap.pool_pages,
                             wave=wave)
                tracer.gauge("pool.live_refs",
                             sum(snap.pool_refs.values()), cat="metrics",
                             per_home=snap.pool_refs, wave=wave)
            tracer.gauge("sched.utilisation", round(utilisation, 4),
                         cat="metrics", wave=wave)
        return snap

    # ------------------------------------------------------------ aggregates
    def queue_depth_max(self) -> Dict[int, int]:
        out: Dict[int, int] = {}
        for s in self.snapshots:
            for h, d in s.queue_depth.items():
                out[h] = max(out.get(h, 0), d)
        return out

    def wave_waits(self) -> List[float]:
        return [w for s in self.snapshots for w in s.waits]


def _pct(values, q: float) -> float:
    if not values:
        return 0.0
    return float(np.percentile(np.asarray(values), q))


def summarise(sch) -> Dict[str, Any]:
    """The canonical summary dict for one finished (or running) scheduler.

    A strict superset of the pre-obs ``Scheduler.summary()`` keys, so
    old consumers keep working; the additions are what the registry
    snapshots and the reconciler need (pool totals, per-home queue-depth
    maxima, config echoes).
    """
    s = sch.stats
    reg: MetricsRegistry = sch.metrics
    state = sch.state
    pool = {h: {"pages": len(p), "refs": sum(pg.refs for pg in p)}
            for h, p in state.pools}
    placements_with_blocks = s.prefix_hits_full + s.prefix_hits_partial
    return {
        "policy": sch.policy,
        "n_slots": sch.n_slots,
        "n_homes": len(sch.homes),
        "homes": list(sch.homes),
        "homes_per_pod": sch.homes_per_pod,
        "served": s.served,
        "tokens_out": s.tokens_out,
        "waves": s.waves,
        "steps": s.steps,
        "utilisation": round(sch.utilisation(), 4),
        "wait_p50": s.wait_pct(50.0),
        "wait_p99": s.wait_pct(99.0),
        "relayout_bytes": s.relayout_bytes,
        "inter_pod_bytes": s.inter_pod_bytes,
        "intra_pod_bytes": s.intra_pod_bytes,
        "relayout_events": s.relayout_events,
        "affinity_hits": s.affinity_hits,
        "pages_attached": s.pages_attached,
        "prefix_hits_full": s.prefix_hits_full,
        "prefix_hits_partial": s.prefix_hits_partial,
        "prefill_rows_saved": round(sch.prefill_rows_saved(), 2),
        "prefix_hit_rate": (round(placements_with_blocks / s.served, 4)
                            if s.served else 0.0),
        "per_home": {h: vars(hs).copy() for h, hs in s.homes.items()},
        "pool": pool,
        "pool_pages": sum(v["pages"] for v in pool.values()),
        "pool_live_refs": sum(v["refs"] for v in pool.values()),
        "queue_depth_max": reg.queue_depth_max(),
        "wave_snapshots": len(reg.snapshots),
        "page_size": sch.page_size or 0,
        "page_capacity": sch.page_capacity,
        "prompt_pad": sch.prompt_pad or 0,
        "bytes_per_token": sch.bytes_per_token,
    }


def format_summary(summary: Dict[str, Any]) -> str:
    """The human exit report — one line per home, then totals."""
    lines = [f"# scheduler policy={summary['policy']} "
             f"slots={summary['n_slots']} homes={summary['n_homes']}"
             + (f" homes_per_pod={summary['homes_per_pod']}"
                if summary.get("homes_per_pod") else ""),
             "# home  admitted  spill_in  spill_out  evicted  "
             "relayout_bytes  max_queue"]
    qmax = summary.get("queue_depth_max", {})
    for h in summary["homes"]:
        hs = summary["per_home"][h]
        lines.append(f"#  {h:>3} {hs['admitted']:>9} {hs['spilled_in']:>9} "
                     f"{hs['spilled_out']:>10} {hs['evicted']:>8} "
                     f"{hs['relayout_bytes']:>14} {qmax.get(h, 0):>9}")
    lines.append(
        f"# served={summary['served']} tokens={summary['tokens_out']} "
        f"waves={summary['waves']} steps={summary['steps']:.0f} "
        f"util={summary['utilisation']:.2f} "
        f"wait_p50={summary['wait_p50']:.1f} "
        f"wait_p99={summary['wait_p99']:.1f} "
        f"relayout={summary['relayout_bytes']}B "
        f"(inter_pod={summary['inter_pod_bytes']}B "
        f"intra_pod={summary['intra_pod_bytes']}B)")
    if summary.get("page_capacity"):
        lines.append(
            f"# pages_attached={summary['pages_attached']} "
            f"prefix_hits={summary['prefix_hits_full']}full/"
            f"{summary['prefix_hits_partial']}partial "
            f"prefill_rows_saved={summary['prefill_rows_saved']:.1f} "
            f"pool_pages={summary['pool_pages']} "
            f"live_refs={summary['pool_live_refs']}")
    return "\n".join(lines)


def bench_rows(name: str, summary: Dict[str, Any],
               wall_us: float) -> List[str]:
    """The ``name,us_per_call,derived`` CSV rows `bench_serve` prints.

    Field names and formats are pinned by the committed BENCH_serve.json
    baselines and `compare.py`'s derived-field gates (``tok_s`` /
    ``rows_saved`` on the ``_prefix`` family, ``p50``/``p99`` on the
    ``_wait`` family) — rendering them here is what makes the bench rows,
    the launcher summary and the trace summary the same numbers.
    """
    tokens = summary["tokens_out"]
    tok_s = tokens / (wall_us / 1e6) if wall_us else 0.0
    return [
        f"{name},{wall_us / max(1, tokens):.0f},"
        f"tok_s={tok_s:.0f};served={summary['served']};"
        f"tokens={tokens};steps={summary['steps']:.0f};"
        f"waves={summary['waves']};"
        f"util={summary['utilisation']:.3f};"
        f"pages={summary['pages_attached']};"
        f"hits_full={summary['prefix_hits_full']};"
        f"hits_part={summary['prefix_hits_partial']};"
        f"rows_saved={summary['prefill_rows_saved']:.1f}",
        f"{name}_wait,,"
        f"p50={summary['wait_p50']:.1f};p99={summary['wait_p99']:.1f}",
        f"{name}_relayout,,"
        f"total={summary['relayout_bytes']};"
        f"inter_pod={summary['inter_pod_bytes']};"
        f"intra_pod={summary['intra_pod_bytes']};"
        f"events={summary['relayout_events']};"
        f"affinity_hits={summary['affinity_hits']}",
    ]
