"""Structured observability: tracing, metrics, and trace reconciliation.

The paper's argument is about *where bytes move* — per-tile caches vs the
mesh networks — yet until this package the runtime could only show that
after the fact, as BENCH diffs.  `repro.obs` makes the traffic visible
live and, in the PR 6-9 spirit, *checked*:

``repro.obs.tracelog``
    Zero-dependency structured tracing: `Span`/`Event`/`Counter`/`Gauge`
    primitives, a thread-safe in-memory `Tracer` with a streaming JSONL
    sink and Chrome trace-event (``chrome://tracing`` / Perfetto) export,
    nested span context managers, and a `NullTracer` whose no-op methods
    make instrumented hot paths free when tracing is off (the default).

``repro.obs.metrics``
    The per-home metrics registry: queue depths, bound sessions, KV-pool
    pages/refs/hit-rate, relayout + inter/intra-pod bytes, wave
    utilisation and per-wave wait histograms, snapshotted at wave
    boundaries — and the ONE rendering path (`summarise` ->
    `format_summary` / `bench_rows` / JSON) every consumer shares:
    ``launch/serve`` exit summaries, ``bench_serve`` CSV rows and the
    trace's final ``sched.summary`` event are the same dict.

``repro.obs.reconcile``
    The offline trace validator: replays a trace and *proves* the counter
    identities (charged relayout bytes == scheduler stats == summary;
    pool acquires − releases − invalidations == live refs; every
    off-home placement has a matching charge; the engine's stamped
    per-level bytes == a fresh `exchange_schedule`).  A regression in the
    observability layer itself shows up as a trace-identity failure, not
    a slower BENCH row.  CLI: ``repro.launch.tracelog --validate``.

Import note: this package must stay import-light (the runtime hot paths
import it), so ``reconcile`` — which pulls in `repro.core.engine` — is a
submodule import, never re-exported here.
"""
from repro.obs.tracelog import (NULL_TRACER, Counter, Event, Gauge,
                                NullTracer, Span, Tracer, get_tracer,
                                read_jsonl, set_tracer, to_chrome)

__all__ = ["Tracer", "NullTracer", "Span", "Event", "Counter", "Gauge",
           "NULL_TRACER", "get_tracer", "set_tracer", "read_jsonl",
           "to_chrome"]
