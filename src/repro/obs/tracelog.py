"""Structured per-wave tracing: spans, events, counters, gauges — zero-dep.

One `Tracer` holds an append-only in-memory record list (thread-safe) and
optionally streams every record to a JSONL sink as it is emitted, so a
crashed run still leaves a readable trace.  Records are plain dicts with
a fixed schema (`KINDS`); `to_chrome` converts any record list to the
Chrome trace-event JSON that ``chrome://tracing`` and Perfetto load
directly.

The four primitives:

``Span``      a timed region (``with tracer.span("sched.form_wave", ...)``),
              nested via an explicit per-thread stack (children record
              their parent's name); emitted at exit with its duration.
              ``Span.set(**kw)`` annotates after the fact, ``Span.event``
              emits an instant event inside the span.
``Event``     an instant decision point ("affinity hit", "charge", ...).
``Counter``   a monotonically accumulated value; each emission carries the
              increment *and* the running total.
``Gauge``     a sampled level (queue depth, live refs) — no accumulation.

`NullTracer` implements the same surface as no-ops returning singletons,
so instrumented hot paths cost one attribute load + one no-op call when
tracing is off — the production default (`NULL_TRACER`).  Code that wants
to skip even argument construction guards on ``tracer.enabled``.

A process-global default tracer (`get_tracer` / `set_tracer`) exists for
layers with no constructor to thread a tracer through (the engine's
eager sort entry); everything else takes an explicit ``tracer=``.

Timestamps are wall-clock microseconds since the tracer's epoch (what
Chrome wants); deterministic simulated clocks (the scheduler's wave
units) ride in ``args`` (``now=...``) so reconciliation never depends on
wall time.
"""
from __future__ import annotations

import io
import json
import threading
import time
from typing import Any, Dict, Iterable, List, NamedTuple, Optional, Union

#: record schema: every record is {"kind", "name", "cat", "ts", "tid",
#: "args"}; spans add "dur" and "parent", counters add "value" + "total",
#: gauges add "value".
KINDS = ("span", "event", "counter", "gauge")

#: trace schema version, stamped as the first record of every sink
SCHEMA = 1


class Event(NamedTuple):
    """An instant record (also the return of `Tracer.event`)."""
    name: str
    ts: float
    cat: str = ""
    args: Optional[Dict[str, Any]] = None


class Counter(NamedTuple):
    """One counter sample: the increment and the running total."""
    name: str
    value: float
    total: float


class Gauge(NamedTuple):
    """One sampled level."""
    name: str
    value: float


def _jsonable(v):
    """Coerce numpy scalars / tuples so records always serialise."""
    try:
        json.dumps(v)
        return v
    except TypeError:
        if hasattr(v, "tolist"):             # numpy scalar or array
            return v.tolist()
        if isinstance(v, (tuple, list, set, frozenset)):
            return [_jsonable(x) for x in v]
        if isinstance(v, dict):
            return {str(k): _jsonable(x) for k, x in v.items()}
        return repr(v)


class Span:
    """A timed region; a context manager emitted at ``__exit__``.

    Created by `Tracer.span` — never directly.  Mutating helpers:
    ``set(**kw)`` merges into ``args`` (annotate a span with results
    computed inside it), ``event(name, **kw)`` emits an instant child
    event stamped with this span's name as ``parent``.
    """

    __slots__ = ("_tracer", "name", "cat", "args", "_t0", "parent")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 args: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args
        self._t0 = 0.0
        self.parent = None

    def set(self, **kw) -> "Span":
        self.args.update(kw)
        return self

    def event(self, name: str, cat: Optional[str] = None, **args) -> None:
        args.setdefault("parent", self.name)
        self._tracer.event(name, cat=self.cat if cat is None else cat,
                           **args)

    def __enter__(self) -> "Span":
        self._t0 = self._tracer._now()
        stack = self._tracer._stack()
        self.parent = stack[-1].name if stack else None
        stack.append(self)
        return self

    def __exit__(self, *exc) -> bool:
        stack = self._tracer._stack()
        if stack and stack[-1] is self:
            stack.pop()
        t1 = self._tracer._now()
        self._tracer._emit({"kind": "span", "name": self.name,
                            "cat": self.cat, "ts": self._t0,
                            "dur": t1 - self._t0, "parent": self.parent,
                            "args": self.args})
        return False


class _NullSpan:
    """The free span: every method is a no-op returning itself."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **kw):
        return self

    def event(self, name, cat=None, **args):
        pass


_NULL_SPAN = _NullSpan()


class NullTracer:
    """The disabled tracer: same surface, nothing recorded, ~zero cost.

    Hot paths are instrumented unconditionally against this default;
    code that would *build* expensive args first guards on ``enabled``.
    """

    enabled = False

    def span(self, name, cat="", **args):
        return _NULL_SPAN

    def event(self, name, cat="", **args):
        pass

    def count(self, name, value=1, cat="", **args):
        pass

    def gauge(self, name, value, cat="", **args):
        pass

    def records(self):
        return []

    def close(self):
        pass


NULL_TRACER = NullTracer()


class Tracer:
    """Thread-safe in-memory trace with an optional streaming JSONL sink.

    ``sink`` is a path or writable text file; every record is written as
    one JSON line the moment it is emitted (the in-memory list is kept
    either way, so `to_chrome`/`records` work without re-reading).  The
    first sinked line is a ``trace.meta`` event carrying the schema
    version.  ``meta`` key/values ride in that header record — stamp the
    run's configuration there (policy, mesh, page_size, ...).
    """

    enabled = True

    def __init__(self, sink: Optional[Union[str, io.TextIOBase]] = None,
                 **meta):
        self._lock = threading.Lock()
        self._records: List[Dict[str, Any]] = []
        self._totals: Dict[str, float] = {}
        self._local = threading.local()
        self._epoch = time.perf_counter()
        self._file = None
        self._own_file = False
        if isinstance(sink, str):
            self._file = open(sink, "w")
            self._own_file = True
        elif sink is not None:
            self._file = sink
        self.event("trace.meta", cat="trace", schema=SCHEMA, **meta)

    # ------------------------------------------------------------ internals
    def _now(self) -> float:
        return (time.perf_counter() - self._epoch) * 1e6   # us since epoch

    def _stack(self) -> List[Span]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _emit(self, rec: Dict[str, Any]) -> None:
        rec.setdefault("tid", threading.get_ident() & 0xFFFF)
        rec["args"] = {k: _jsonable(v)
                       for k, v in (rec.get("args") or {}).items()}
        with self._lock:
            self._records.append(rec)
            if self._file is not None:
                self._file.write(json.dumps(rec) + "\n")

    # ------------------------------------------------------------ primitives
    def span(self, name: str, cat: str = "", **args) -> Span:
        return Span(self, name, cat, args)

    def event(self, name: str, cat: str = "", **args) -> None:
        self._emit({"kind": "event", "name": name, "cat": cat,
                    "ts": self._now(), "args": args})

    def count(self, name: str, value: float = 1, cat: str = "",
              **args) -> None:
        with self._lock:
            total = self._totals[name] = self._totals.get(name, 0) + value
        self._emit({"kind": "counter", "name": name, "cat": cat,
                    "ts": self._now(), "value": value, "total": total,
                    "args": args})

    def gauge(self, name: str, value: float, cat: str = "", **args) -> None:
        self._emit({"kind": "gauge", "name": name, "cat": cat,
                    "ts": self._now(), "value": value, "args": args})

    # ------------------------------------------------------------ export
    def records(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self._records)

    def total(self, name: str) -> float:
        with self._lock:
            return self._totals.get(name, 0)

    def chrome_trace(self) -> Dict[str, Any]:
        return to_chrome(self.records())

    def dump_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for rec in self.records():
                f.write(json.dumps(rec) + "\n")

    def dump_chrome(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)

    def close(self) -> None:
        with self._lock:
            if self._file is not None:
                self._file.flush()
                if self._own_file:
                    self._file.close()
                self._file = None


def to_chrome(records: Iterable[Dict[str, Any]]) -> Dict[str, Any]:
    """Convert trace records to Chrome trace-event JSON.

    Spans become complete (``ph="X"``) events, instants become ``ph="i"``
    (thread-scoped), counters and gauges become ``ph="C"`` counter tracks
    (the counter's running total, so the track is monotone).  Load the
    result in ``chrome://tracing`` or https://ui.perfetto.dev.
    """
    out = []
    for r in records:
        base = {"name": r["name"], "cat": r.get("cat") or "trace",
                "pid": 0, "tid": r.get("tid", 0), "ts": r["ts"],
                "args": r.get("args") or {}}
        kind = r["kind"]
        if kind == "span":
            out.append({**base, "ph": "X", "dur": r["dur"]})
        elif kind == "event":
            out.append({**base, "ph": "i", "s": "t"})
        elif kind == "counter":
            out.append({**base, "ph": "C",
                        "args": {"total": r.get("total", r.get("value"))}})
        elif kind == "gauge":
            out.append({**base, "ph": "C", "args": {"value": r["value"]}})
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def read_jsonl(path: str) -> List[Dict[str, Any]]:
    """Load a JSONL trace back into the record-dict list form."""
    records = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                records.append(json.loads(line))
    return records


# ---------------------------------------------------------------------------
# the process-global default tracer (layers without a constructor to thread
# an explicit tracer through — the engine's eager sort entry)
# ---------------------------------------------------------------------------
_GLOBAL: Union[Tracer, NullTracer] = NULL_TRACER


def get_tracer() -> Union[Tracer, NullTracer]:
    return _GLOBAL


def set_tracer(tracer: Optional[Union[Tracer, NullTracer]]
               ) -> Union[Tracer, NullTracer]:
    """Install the process-global tracer; returns the previous one.
    ``None`` resets to `NULL_TRACER`."""
    global _GLOBAL
    prev = _GLOBAL
    _GLOBAL = tracer if tracer is not None else NULL_TRACER
    return prev
