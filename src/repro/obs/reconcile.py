"""Offline trace validation: replay a trace, prove the counter identities.

A trace is evidence, not truth — this module makes it truth by replaying
the per-event records and checking them against the final ``sched.summary``
(the same canonical dict the launcher prints and the bench rows render)
and against the *analytic* cost model (`repro.core.engine.exchange_schedule`
recomputed from each ``engine.sort`` span's stamped arguments).  A bug in
the instrumentation, the scheduler's charge accounting, or the metrics
registry shows up as an identity failure here, not as a quietly wrong
BENCH row.

The identities (per reconciliation segment — a trace may hold several
serving runs; each ``sched.summary`` event closes one):

I-bytes     sum of ``sched.charge`` nbytes == summary ``relayout_bytes``,
            split exactly into ``inter_pod_bytes`` / ``intra_pod_bytes``
            by the charge's ``inter_pod`` flag, and per-home by ``dst``;
            the count of nonzero charges == ``relayout_events``.
I-offhome   replaying the ``sched.place`` events in decision order with
            the scheduler's same-wave cache-copy-site rule (a session's
            sites start at its pre-wave ``bound_home`` and accumulate
            every home it lands on this wave) predicts *exactly* which
            placements carry a charge — the charge events' rid set must
            equal the predicted set: every off-home decode is paid for,
            and nothing is double-charged.
I-pool      sum(``pool.acquire`` refs) − sum(``pool.release`` refs) −
            sum(``pool.invalidate`` refs) == summary ``pool_live_refs``,
            per home and in total (events carry actual state deltas, so
            the identity survives mid-flight force-invalidation).
I-serve     placements == ``served``; distinct wave ids == ``waves``;
            sum of placement ``attached`` == ``pages_attached``; the
            placement waits reproduce ``wait_p50``/``wait_p99``; under
            the homed policy the unspilled on-bound-home placements
            count == ``affinity_hits``.
I-engine    each ``engine.sort`` span's child ``engine.exchange_level``
            events equal a fresh ``exchange_schedule(n, sizes, policy)``
            recomputed from the span's stamped args, record for record.

Schema checks run first (every record well-formed, ``trace.meta`` header
present with a known schema version); a malformed trace is rejected
before any identity is attempted.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import numpy as np

from repro.obs.tracelog import KINDS, SCHEMA

#: identity tolerance for float comparisons (wait percentiles)
_EPS = 1e-6


class ReconcileError(AssertionError):
    """A trace failed schema validation or a counter identity."""


def _fail(name: str, msg: str):
    raise ReconcileError(f"[{name}] {msg}")


# --------------------------------------------------------------------- schema
def check_schema(records: List[Dict[str, Any]]) -> None:
    """Structural validation: reject malformed records before replay."""
    if not records:
        _fail("schema", "empty trace")
    meta = records[0]
    if meta.get("name") != "trace.meta":
        _fail("schema", "first record is not the trace.meta header")
    if meta.get("args", {}).get("schema") != SCHEMA:
        _fail("schema", f"unknown trace schema "
                        f"{meta.get('args', {}).get('schema')!r} "
                        f"(validator speaks {SCHEMA})")
    for i, r in enumerate(records):
        kind = r.get("kind")
        if kind not in KINDS:
            _fail("schema", f"record {i}: unknown kind {kind!r}")
        if not isinstance(r.get("name"), str) or not r["name"]:
            _fail("schema", f"record {i}: missing name")
        if not isinstance(r.get("ts"), (int, float)):
            _fail("schema", f"record {i}: non-numeric ts")
        if not isinstance(r.get("args"), dict):
            _fail("schema", f"record {i}: args is not a dict")
        if kind == "span" and not isinstance(r.get("dur"), (int, float)):
            _fail("schema", f"record {i}: span without dur")
        if kind in ("counter", "gauge") \
                and not isinstance(r.get("value"), (int, float)):
            _fail("schema", f"record {i}: {kind} without value")


# ------------------------------------------------------------------- segments
def segments(records: List[Dict[str, Any]]
             ) -> List[Tuple[List[Dict[str, Any]], Dict[str, Any]]]:
    """Split a trace into ``(records, summary_args)`` reconciliation
    segments, one per ``sched.summary`` event.  Trailing scheduler events
    with no closing summary are an error (the run died before
    `emit_summary` — nothing to reconcile them against)."""
    segs = []
    cur: List[Dict[str, Any]] = []
    for r in records:
        if r.get("name") == "sched.summary":
            segs.append((cur, r["args"]))
            cur = []
        else:
            cur.append(r)
    dangling = [r["name"] for r in cur
                if r.get("name") in ("sched.place", "sched.charge")]
    if dangling:
        _fail("segments", f"{len(dangling)} scheduler events after the "
                          f"last sched.summary — incomplete run?")
    return segs


def _named(records, name):
    return [r for r in records if r.get("name") == name]


def _homes_int(d: Dict) -> Dict[int, Any]:
    """JSON round-trips int dict keys to strings; undo that."""
    return {int(k): v for k, v in d.items()}


# ----------------------------------------------------------------- identities
def check_charges(records, summary) -> None:
    """I-bytes: charged relayout == scheduler stats == summary bytes."""
    charges = [r["args"] for r in _named(records, "sched.charge")]
    total = sum(c["nbytes"] for c in charges)
    inter = sum(c["nbytes"] for c in charges if c["inter_pod"])
    events = sum(1 for c in charges if c["nbytes"])
    if total != summary["relayout_bytes"]:
        _fail("I-bytes", f"charged {total}B != summary "
                         f"relayout_bytes {summary['relayout_bytes']}B")
    if inter != summary["inter_pod_bytes"]:
        _fail("I-bytes", f"inter-pod charges {inter}B != summary "
                         f"{summary['inter_pod_bytes']}B")
    if total - inter != summary["intra_pod_bytes"]:
        _fail("I-bytes", f"intra-pod charges {total - inter}B != summary "
                         f"{summary['intra_pod_bytes']}B")
    if events != summary["relayout_events"]:
        _fail("I-bytes", f"{events} nonzero charges != relayout_events "
                         f"{summary['relayout_events']}")
    per_home = _homes_int(summary["per_home"])
    by_dst: Dict[int, int] = {}
    for c in charges:
        by_dst[c["dst"]] = by_dst.get(c["dst"], 0) + c["nbytes"]
    for h, hs in per_home.items():
        if by_dst.get(h, 0) != hs["relayout_bytes"]:
            _fail("I-bytes", f"home {h}: charged {by_dst.get(h, 0)}B != "
                             f"per-home relayout {hs['relayout_bytes']}B")


def check_offhome(records, summary) -> None:
    """I-offhome: replay the same-wave site rule; predicted charge set
    must equal the actual charge events' rid set, per wave."""
    places = [r["args"] for r in _named(records, "sched.place")]
    charged: Dict[int, set] = {}
    for r in _named(records, "sched.charge"):
        charged.setdefault(r["args"]["wave"], set()).add(r["args"]["rid"])
    waves: Dict[int, list] = {}
    for p in places:          # record order == decision order
        waves.setdefault(p["wave"], []).append(p)
    for w, plist in waves.items():
        expect = set()
        sites: Dict[Any, set] = {}
        for p in plist:
            sess, bound = p["session"], p["bound_home"]
            if bound is None:
                continue      # fresh session: first landing is free
            s = sites.setdefault(sess, {bound})
            if p["home"] not in s:
                expect.add(p["rid"])
            s.add(p["home"])
        got = charged.get(w, set())
        if expect != got:
            _fail("I-offhome",
                  f"wave {w}: off-home placements {sorted(expect)} vs "
                  f"charge events {sorted(got)} — "
                  f"{'uncharged off-home decode' if expect - got else 'charge with no off-home placement'}")


def check_pool(records, summary) -> None:
    """I-pool: acquires − releases − invalidations == live refs."""
    flow: Dict[int, int] = {}
    for r in _named(records, "pool.acquire"):
        flow[r["args"]["home"]] = \
            flow.get(r["args"]["home"], 0) + r["args"]["refs"]
    for r in _named(records, "pool.release"):
        flow[r["args"]["home"]] = \
            flow.get(r["args"]["home"], 0) - r["args"]["refs"]
    for r in _named(records, "pool.invalidate"):
        flow[r["args"]["home"]] = \
            flow.get(r["args"]["home"], 0) - r["args"]["refs"]
    pool = _homes_int(summary.get("pool", {}))
    for h in set(flow) | set(pool):
        net = flow.get(h, 0)
        live = pool.get(h, {}).get("refs", 0)
        if net != live:
            _fail("I-pool", f"home {h}: acquires-releases net {net} != "
                            f"live refs {live}")
    total = sum(flow.values())
    if total != summary.get("pool_live_refs", 0):
        _fail("I-pool", f"net pinned refs {total} != summary "
                        f"pool_live_refs {summary.get('pool_live_refs', 0)}")


def check_serve(records, summary) -> None:
    """I-serve: placements/waves/waits/attached/affinity vs summary."""
    places = [r["args"] for r in _named(records, "sched.place")]
    if len(places) != summary["served"]:
        _fail("I-serve", f"{len(places)} placements != served "
                         f"{summary['served']}")
    wave_ids = {p["wave"] for p in places}
    if len(wave_ids) != summary["waves"] or \
            (wave_ids and max(wave_ids) != summary["waves"]):
        _fail("I-serve", f"wave ids {sorted(wave_ids)[:8]}... != "
                         f"summary waves {summary['waves']}")
    attached = sum(p["attached"] for p in places)
    if attached != summary["pages_attached"]:
        _fail("I-serve", f"placed attached pages {attached} != "
                         f"pages_attached {summary['pages_attached']}")
    waits = [p["wait"] for p in places]
    for q, key in ((50.0, "wait_p50"), (99.0, "wait_p99")):
        got = float(np.percentile(np.asarray(waits), q)) if waits else 0.0
        if abs(got - summary[key]) > _EPS:
            _fail("I-serve", f"placement waits give {key}={got:.4f} != "
                             f"summary {summary[key]:.4f}")
    if summary["policy"] == "homed":
        hits = sum(1 for p in places
                   if p["spilled_from"] is None
                   and p["bound_home"] == p["home"])
        if hits != summary["affinity_hits"]:
            _fail("I-serve", f"{hits} on-bound-home placements != "
                             f"affinity_hits {summary['affinity_hits']}")


def check_engine(records) -> None:
    """I-engine: stamped per-level budgets == a fresh exchange_schedule.

    Recomputes the analytic schedule from each ``engine.sort`` span's
    stamped (n, sizes, policy, num_workers, itemsize, local_phase) and
    compares record-for-record with the span's stamped levels — the
    trace carries the analytic budget, and the budget must be *right*.
    """
    sorts = _named(records, "engine.sort")
    if not sorts:
        return
    from repro.core.engine import exchange_schedule
    from repro.core.homing import Homing
    from repro.core.localisation import LocalisationPolicy
    levels: Dict[int, list] = {}
    for r in _named(records, "engine.exchange_level"):
        a = dict(r["args"])
        a.pop("parent", None)
        levels.setdefault(a.pop("call"), []).append(a)
    for r in sorts:
        a = r["args"]
        pol = a["policy"]
        policy = LocalisationPolicy(
            localised=pol["localised"],
            static_mapping=pol["static_mapping"],
            homing=Homing[pol["homing"]], outer=pol["outer"])
        want = exchange_schedule(
            a["n"], tuple(a["sizes"]), policy,
            num_workers=a["num_workers"], itemsize=a["itemsize"],
            local_phase=a["local_phase"])
        got = levels.get(a["call"], [])
        if got != want:
            _fail("I-engine",
                  f"engine.sort call {a['call']} (n={a['n']}, "
                  f"sizes={a['sizes']}): stamped {len(got)} level records "
                  f"!= analytic schedule {len(want)}"
                  + next((f"; first diff at record {i}: {g} != {w}"
                          for i, (g, w) in enumerate(zip(got, want))
                          if g != w), ""))


# ----------------------------------------------------------------- entrypoint
def reconcile(records: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Validate a full trace; returns a report dict or raises
    `ReconcileError` on the first failed check.

    ``{"segments": N, "checks": [names run], "served": total,
    "relayout_bytes": total, "engine_sorts": N}``
    """
    check_schema(records)
    segs = segments(records)
    served = relayout = 0
    for recs, summary in segs:
        check_charges(recs, summary)
        check_offhome(recs, summary)
        check_pool(recs, summary)
        check_serve(recs, summary)
        served += summary["served"]
        relayout += summary["relayout_bytes"]
    check_engine(records)
    return {"segments": len(segs),
            "checks": ["schema", "I-bytes", "I-offhome", "I-pool",
                       "I-serve", "I-engine"],
            "served": served, "relayout_bytes": relayout,
            "engine_sorts": len(_named(records, "engine.sort"))}
